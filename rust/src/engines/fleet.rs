//! The shared fleet/dispatch layer carved out of the four engines.
//!
//! Every engine used to re-implement the same four pieces privately; they
//! now live here, behind one interface each:
//!
//! * [`SeqTable`] — the sequence table (`Vec<Option<Seq>>` + id
//!   allocation). Ids are assigned in admission order and never reused;
//!   a finished sequence's slot is emptied but keeps its index so in-flight
//!   timers referencing the id stay valid.
//! * [`Router`] — the pluggable routing interface over per-instance
//!   [`InstanceLoad`] snapshots, unifying vLLM's `RouterPolicy` scoring,
//!   BanaServe's Alg 2 `pick`/`pick_rotating`, and DistServe's pool picks.
//!   Each implementation preserves the exact comparison and tie-break
//!   order of the engine it was extracted from.
//! * [`FleetEvent`] — the typed timer-dispatch table replacing the
//!   hand-rolled `match t.tag` blocks. Encoding is lossless over
//!   [`crate::sim::Timer`]'s `(tag, a, b)` wire format, so refactored
//!   engines replay identical event streams.
//! * [`admit_or_drop`] — FCFS admission control (`request_fits`
//!   rejection + drop accounting), previously copy-pasted four times.
//!
//! On top of the shared layer sits the **elastic fleet**: a windowed-load
//! [`Autoscaler`] that turns per-device [`FleetLoad`] snapshots into
//! [`ScaleDecision`]s (scale-out / drain-one / hold) under min/max fleet
//! bounds and a cooldown. The engines own execution: adding worker state
//! for a new device, or draining and releasing a victim.

use super::common::{self, tags, Seq};
use crate::cluster::{Device, GpuSpec};
use crate::config::AutoscaleConfig;
use crate::forecast::ForecastSignal;
use crate::metrics::{Collector, TimeSeries};
use crate::model::ModelSpec;
use crate::sim::Timer;
use crate::util::prng::Rng;
use crate::workload::Request;

// ---------------------------------------------------------------------------
// Sequence table
// ---------------------------------------------------------------------------

/// The fleet-wide sequence table. Owns every admitted [`Seq`]; engines
/// refer to sequences by the `u64` id this table allocates.
#[derive(Debug, Default)]
pub struct SeqTable {
    slots: Vec<Option<Seq>>,
}

impl SeqTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Admit a sequence; returns its id (= slot index, allocation order).
    pub fn insert(&mut self, seq: Seq) -> u64 {
        let sid = self.slots.len() as u64;
        self.slots.push(Some(seq));
        sid
    }

    /// Total slots ever allocated (live + finished).
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn get(&self, sid: u64) -> Option<&Seq> {
        self.slots.get(sid as usize).and_then(|s| s.as_ref())
    }

    pub fn get_mut(&mut self, sid: u64) -> Option<&mut Seq> {
        self.slots.get_mut(sid as usize).and_then(|s| s.as_mut())
    }

    /// Borrow a live sequence; panics if the id was never allocated or the
    /// sequence already finished (engine logic error).
    pub fn seq(&self, sid: u64) -> &Seq {
        self.slots[sid as usize].as_ref().expect("live seq")
    }

    pub fn seq_mut(&mut self, sid: u64) -> &mut Seq {
        self.slots[sid as usize].as_mut().expect("live seq")
    }

    /// Drop a finished sequence's payload; the slot index stays allocated.
    pub fn remove(&mut self, sid: u64) -> Option<Seq> {
        self.slots[sid as usize].take()
    }

    /// The raw slot view `plan_prefill`/`plan_decode` consume.
    pub fn slots(&self) -> &[Option<Seq>] {
        &self.slots
    }
}

// ---------------------------------------------------------------------------
// Admission
// ---------------------------------------------------------------------------

/// FCFS admission control shared by all engines: a request whose prompt +
/// full output can never fit one device's post-weight HBM is dropped (and
/// counted) instead of deadlocking the head of the queue.
///
/// Returns true when the request may be admitted.
pub fn admit_or_drop(
    spec: &ModelSpec,
    gpu: &GpuSpec,
    req: &Request,
    col: &mut Collector,
) -> bool {
    if common::request_fits(spec, gpu, req) {
        return true;
    }
    log::debug!(
        "dropping request {} (ctx {} + out {} exceeds device KV)",
        req.id,
        req.prompt_len,
        req.output_len
    );
    col.dropped += 1;
    false
}

// ---------------------------------------------------------------------------
// Routing
// ---------------------------------------------------------------------------

/// Snapshot of one routable instance, superset of what every router needs.
/// Engines fill the fields their policy consumes and zero the rest.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InstanceLoad {
    /// Engine-level instance/device index (what a pick maps back to).
    pub idx: usize,
    /// Waiting + running sequences.
    pub load_seqs: usize,
    /// Waiting-queue depth.
    pub queue_len: usize,
    /// Running-set size (decode placement).
    pub running: usize,
    /// Normalized utilization U ∈ [0, 2] (BanaServe Eq 37).
    pub u: f64,
    /// Fraction of the request's cacheable prefix resident at this
    /// instance's prefix cache (vLLM cache-aware scoring).
    pub cache_hit: f64,
    /// Free HBM bytes (DistServe decode placement).
    pub mem_free: u64,
    /// Relative capacity weight of the backing device (heterogeneous
    /// fleets; [`crate::cluster::GpuSpec::weight`]). Every policy divides
    /// its load counters by this, so a 2x device absorbs 2x the work
    /// before looking equally loaded. 1.0 = the homogeneous baseline;
    /// with uniform weights the normalization is an exact identity
    /// (x / 1.0 == x in IEEE), so picks are byte-identical to the
    /// pre-weight integer comparisons.
    pub weight: f64,
}

impl InstanceLoad {
    /// A zeroed snapshot for `idx` — callers overwrite what they use.
    pub fn at(idx: usize) -> Self {
        InstanceLoad {
            idx,
            load_seqs: 0,
            queue_len: 0,
            running: 0,
            u: 0.0,
            cache_hit: 0.0,
            mem_free: 0,
            weight: 1.0,
        }
    }

    /// Capacity-normalized resident-sequence load.
    #[inline]
    pub fn norm_load(&self) -> f64 {
        self.load_seqs as f64 / self.weight.max(1e-9)
    }

    /// Capacity-normalized queue depth.
    #[inline]
    pub fn norm_queue(&self) -> f64 {
        self.queue_len as f64 / self.weight.max(1e-9)
    }

    /// Capacity-normalized running-set size.
    #[inline]
    pub fn norm_running(&self) -> f64 {
        self.running as f64 / self.weight.max(1e-9)
    }
}

/// A routing policy. `pick` returns the POSITION within `loads` of the
/// chosen instance (None when `loads` is empty); callers map back through
/// `loads[pos].idx`. Policies may keep state (round-robin cursors).
pub trait Router {
    fn pick(&mut self, loads: &[InstanceLoad]) -> Option<usize>;
    fn name(&self) -> &'static str;
}

// ---------------------------------------------------------------------------
// Incremental load tracking
// ---------------------------------------------------------------------------

/// Persistent per-engine load tracking: one [`InstanceLoad`] per routable
/// instance, kept up to date at admit / step / finish / drain transitions,
/// plus a reusable scratch buffer for filtered router views.
///
/// This replaces the per-arrival snapshot rebuild (a fresh
/// `Vec<InstanceLoad>` allocation + full refill on EVERY routed event) the
/// engines used to do. Two usage modes:
///
/// * **Maintained slice** — engines whose router consumes cheap counters
///   (queue depth, resident sequences) sync them via [`LoadBook::set_queue`]
///   at the few transition points that mutate them and hand
///   [`LoadBook::loads`] straight to [`Router::pick`]: zero per-arrival
///   work beyond the pick itself (vLLM, HFT).
/// * **Filtered scratch** — engines that route over a filtered or derived
///   view (BanaServe's Alg 2 over unfrozen prefill-capable devices,
///   DistServe's role pools) fill the reusable scratch via
///   [`LoadBook::filtered`] / [`LoadBook::fill`] instead of collecting a
///   fresh `Vec`: allocation-free after warm-up.
///
/// The equivalence property test in `tests/prop_engines.rs` pins a
/// maintained book against rebuilt-from-scratch snapshots across random
/// transition streams.
#[derive(Debug, Default)]
pub struct LoadBook {
    entries: Vec<InstanceLoad>,
    scratch: Vec<InstanceLoad>,
    /// Optional tournament-tree index over the maintained entries (opt-in
    /// via [`LoadBook::enable_index`]; large fleets only — see the routing
    /// ownership rules in [`crate::engines`]). `None` costs nothing on the
    /// sync hot paths.
    index: Option<Box<BookIndex>>,
}

impl LoadBook {
    pub fn new() -> Self {
        Self::default()
    }

    /// A book over `n` instances, all zeroed.
    pub fn with_instances(n: usize) -> Self {
        LoadBook {
            entries: (0..n).map(InstanceLoad::at).collect(),
            scratch: Vec::new(),
            index: None,
        }
    }

    /// Append a zeroed entry for a new (scaled-out) instance; returns its
    /// index. Instance indices are stable — drained instances keep their
    /// entry (engines filter them out of router views). With an index
    /// enabled the new entry joins every tree as eligible (engines mark it
    /// ineligible/frozen through the usual transition hooks).
    pub fn add_instance(&mut self) -> usize {
        let idx = self.entries.len();
        self.entries.push(InstanceLoad::at(idx));
        if let Some(ix) = self.index.as_mut() {
            ix.eligible.push(true);
            ix.dirty_mark.push(false);
            let (entries, eligible) = (&self.entries, &ix.eligible);
            if entries.len() > ix.trees.first().map_or(0, |t| t.cap) {
                for t in ix.trees.iter_mut() {
                    t.rebuild(entries, eligible);
                }
            } else {
                for t in ix.trees.iter_mut() {
                    t.update(idx, entries, eligible);
                }
            }
        }
        idx
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, i: usize) -> &InstanceLoad {
        &self.entries[i]
    }

    pub fn entry_mut(&mut self, i: usize) -> &mut InstanceLoad {
        self.mark_dirty(i);
        &mut self.entries[i]
    }

    /// The maintained full slice, in instance order — what a filter-free
    /// router reads directly.
    pub fn loads(&self) -> &[InstanceLoad] {
        &self.entries
    }

    /// O(1) sync of the queue counters for instance `i` — the common
    /// admit/step/finish transition hook. With an index enabled this only
    /// marks the entry dirty (O(1)); the deferred O(log n) tree repair
    /// happens at the next indexed pick.
    pub fn set_queue(&mut self, i: usize, queue_len: usize, load_seqs: usize) {
        let e = &mut self.entries[i];
        e.queue_len = queue_len;
        e.load_seqs = load_seqs;
        self.mark_dirty(i);
    }

    // --- tournament-tree index (opt-in, large fleets) ----------------------

    /// Build a tournament-tree index with one tree per key over the current
    /// entries (all eligible). From here on `set_queue`/`entry_mut` mark
    /// entries dirty and [`LoadBook::pick_indexed`] serves exact O(log n)
    /// best-of-fleet picks.
    pub fn enable_index(&mut self, keys: &[TreeKey]) {
        let n = self.entries.len();
        let mut ix = Box::new(BookIndex {
            trees: keys.iter().map(|&k| TournamentTree::new(k)).collect(),
            eligible: vec![true; n],
            dirty: Vec::new(),
            dirty_mark: vec![false; n],
            ties: Vec::new(),
        });
        for t in ix.trees.iter_mut() {
            t.rebuild(&self.entries, &ix.eligible);
        }
        self.index = Some(ix);
    }

    /// Is a tournament index active on this book?
    pub fn index_enabled(&self) -> bool {
        self.index.is_some()
    }

    /// Mark instance `i` (in)eligible for indexed picks — the membership
    /// hook engines call at scale-out / drain / fail / recover transitions.
    /// No-op without an index. O(1); the tree repair is deferred.
    pub fn set_eligible(&mut self, i: usize, on: bool) {
        if let Some(ix) = self.index.as_mut() {
            if i < ix.eligible.len() {
                ix.eligible[i] = on;
            }
        }
        self.mark_dirty(i);
    }

    #[inline]
    fn mark_dirty(&mut self, i: usize) {
        if let Some(ix) = self.index.as_mut() {
            if i < ix.dirty_mark.len() && !ix.dirty_mark[i] {
                ix.dirty_mark[i] = true;
                ix.dirty.push(i);
            }
        }
    }

    /// Repair every tree for the entries dirtied since the last pick
    /// (O(dirty · log n), amortized over the syncs that dirtied them).
    fn flush_index(&mut self) {
        let Some(ix) = self.index.as_mut() else { return };
        if ix.dirty.is_empty() {
            return;
        }
        let entries = &self.entries;
        let (dirty, marks, eligible, trees) = (
            &mut ix.dirty,
            &mut ix.dirty_mark,
            &ix.eligible,
            &mut ix.trees,
        );
        for t in trees.iter_mut() {
            for &i in dirty.iter() {
                t.update(i, entries, eligible);
            }
        }
        for &i in dirty.iter() {
            marks[i] = false;
        }
        dirty.clear();
    }

    /// Exact O(log n) pick: the position of the best ELIGIBLE entry under
    /// `key`, identical to the corresponding router's linear scan over the
    /// eligible subset (pinned by `tests/prop_routing.rs`). None when no
    /// tree for `key` was enabled or every entry is ineligible.
    pub fn pick_indexed(&mut self, key: TreeKey) -> Option<usize> {
        self.flush_index();
        let ix = self.index.as_ref()?;
        ix.trees.iter().find(|t| t.key == key)?.best()
    }

    /// Indexed form of [`pick_load_aware`] (BanaServe Alg 2): the
    /// LoadAwareU tree serves the min-U pick and the near-tie rotation set
    /// (tree descent pruned on the `TIE_EPS` band), the LoadAwareQ tree the
    /// overloaded-everywhere fallback. Requires both trees enabled.
    pub fn pick_indexed_load_aware(&mut self, delta_l: f64, rr: usize) -> Option<usize> {
        self.flush_index();
        let entries = &self.entries;
        let ix = self.index.as_mut()?;
        let tu = ix.trees.iter().position(|t| t.key == TreeKey::LoadAwareU)?;
        let tq = ix.trees.iter().position(|t| t.key == TreeKey::LoadAwareQ)?;
        let least = ix.trees[tu].best()?;
        if entries[least].u >= delta_l {
            // overloaded everywhere: lowest queue wins (Alg 2 line 17)
            return ix.trees[tq].best();
        }
        let (min_u, min_q) = (entries[least].u, entries[least].norm_queue());
        let (trees, ties) = (&ix.trees, &mut ix.ties);
        ties.clear();
        trees[tu].collect_ties(1, entries, min_u, min_q, ties);
        let want = rr % ties.len().max(1);
        ties.get(want).copied()
    }

    /// Fill the scratch buffer with the maintained entries passing `keep`
    /// and return it — the reusable filtered router view.
    pub fn filtered(&mut self, mut keep: impl FnMut(&InstanceLoad) -> bool) -> &[InstanceLoad] {
        self.scratch.clear();
        let (entries, scratch) = (&self.entries, &mut self.scratch);
        scratch.extend(entries.iter().filter(|&l| keep(l)).copied());
        scratch
    }

    /// Clear and hand out the scratch buffer for a custom fill (derived
    /// fields like BanaServe's windowed `U` or DistServe's live free-memory
    /// reads). Read the result back via [`LoadBook::scratch`].
    pub fn fill(&mut self) -> &mut Vec<InstanceLoad> {
        self.scratch.clear();
        &mut self.scratch
    }

    /// The scratch buffer as last filled.
    pub fn scratch(&self) -> &[InstanceLoad] {
        &self.scratch
    }
}

/// Strict round robin over the snapshot order.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    /// Allocation-free fast path: round robin needs only the instance
    /// count, so per-arrival hot paths skip building snapshots entirely.
    pub fn pick_n(&mut self, n: usize) -> Option<usize> {
        if n == 0 {
            return None;
        }
        let i = self.next % n;
        self.next += 1;
        Some(i)
    }
}

impl Router for RoundRobin {
    fn pick(&mut self, loads: &[InstanceLoad]) -> Option<usize> {
        self.pick_n(loads.len())
    }

    fn name(&self) -> &'static str {
        "round-robin"
    }
}

/// Min (load_seqs/w, queue_len/w, idx) — vLLM's `LeastLoaded`, capacity-
/// normalized. With uniform weights the float comparisons reproduce the
/// historical integer tuple ordering exactly (small counts are exact in
/// f64 and `total_cmp` agrees with `cmp` on them).
#[derive(Debug, Default)]
pub struct LeastLoaded;

impl Router for LeastLoaded {
    fn pick(&mut self, loads: &[InstanceLoad]) -> Option<usize> {
        loads
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                a.norm_load()
                    .total_cmp(&b.norm_load())
                    .then(a.norm_queue().total_cmp(&b.norm_queue()))
                    .then(a.idx.cmp(&b.idx))
            })
            .map(|(p, _)| p)
    }

    fn name(&self) -> &'static str {
        "least-loaded"
    }
}

/// Min (queue_len/w, load_seqs/w, idx) — DistServe's prefill dispatch,
/// capacity-normalized.
#[derive(Debug, Default)]
pub struct LeastQueue;

impl Router for LeastQueue {
    fn pick(&mut self, loads: &[InstanceLoad]) -> Option<usize> {
        loads
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                a.norm_queue()
                    .total_cmp(&b.norm_queue())
                    .then(a.norm_load().total_cmp(&b.norm_load()))
                    .then(a.idx.cmp(&b.idx))
            })
            .map(|(p, _)| p)
    }

    fn name(&self) -> &'static str {
        "least-queue"
    }
}

/// Max (mem_free, fewest running/w) — DistServe's decode placement. Free
/// memory is absolute bytes (a bigger HBM IS the capacity difference); only
/// the running-set tie-break normalizes. Ties resolve to the LAST maximal
/// candidate, exactly as the original `max_by_key` did.
#[derive(Debug, Default)]
pub struct MostFreeMem;

impl Router for MostFreeMem {
    fn pick(&mut self, loads: &[InstanceLoad]) -> Option<usize> {
        loads
            .iter()
            .enumerate()
            .max_by(|(_, a), (_, b)| {
                a.mem_free
                    .cmp(&b.mem_free)
                    .then(b.norm_running().total_cmp(&a.norm_running()))
            })
            .map(|(p, _)| p)
    }

    fn name(&self) -> &'static str {
        "most-free-mem"
    }
}

/// vLLM/SGLang's cache-aware scoring: `w_cache·hit − w_load·(load/max)`,
/// highest score wins — the policy whose positive-feedback skew Fig 2a
/// demonstrates. Load is capacity-normalized before the max-scaling, so a
/// heavier device tolerates proportionally more residents. Ties resolve to
/// the LAST maximal candidate, exactly as the original `max_by` loop did.
#[derive(Debug)]
pub struct CacheAware {
    pub w_cache: f64,
    pub w_load: f64,
}

impl Router for CacheAware {
    fn pick(&mut self, loads: &[InstanceLoad]) -> Option<usize> {
        let max_load = loads
            .iter()
            .map(|l| l.norm_load())
            .fold(0.0_f64, f64::max)
            .max(1.0);
        let score = |l: &InstanceLoad| {
            self.w_cache * l.cache_hit - self.w_load * (l.norm_load() / max_load)
        };
        loads
            .iter()
            .enumerate()
            .max_by(|(_, a), (_, b)| score(a).total_cmp(&score(b)))
            .map(|(p, _)| p)
    }

    fn name(&self) -> &'static str {
        "cache-aware"
    }
}

/// Near-tie band of BanaServe's Alg 2 rotation: candidates whose `U` sits
/// within this of the fleet minimum (at equal normalized queue depth) share
/// the rotating tie-break. Shared between the linear-scan pick and the
/// indexed tree descent so the two stay bit-identical.
pub const TIE_EPS: f64 = 0.05;

/// BanaServe's Alg 2 load-aware pick with rotating tie-breaks, stateless
/// form: engines that route from `&self` contexts keep their own rotation
/// cursor and call this directly; [`LoadAware`] wraps it for the trait.
///
/// This is a faithful, allocation-free port of
/// `banaserve::scheduler::pick_rotating` onto fleet snapshots (the fleet
/// layer must not depend on an engine module); a parity property test in
/// `tests/prop_engines.rs` pins the two implementations together (at
/// uniform weight — `u` is already a per-device utilization, so only the
/// queue tie-breaks are capacity-normalized here).
pub fn pick_load_aware(loads: &[InstanceLoad], delta_l: f64, rr: usize) -> Option<usize> {
    if loads.is_empty() {
        return None;
    }
    let least = loads
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| {
            a.u.total_cmp(&b.u)
                .then(a.norm_queue().total_cmp(&b.norm_queue()))
                .then(a.idx.cmp(&b.idx))
        })
        .map(|(i, _)| i)
        .unwrap();
    if loads[least].u >= delta_l {
        // overloaded everywhere: lowest queue wins (Alg 2 line 17)
        return loads
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                a.norm_queue()
                    .total_cmp(&b.norm_queue())
                    .then(a.u.total_cmp(&b.u))
                    .then(a.idx.cmp(&b.idx))
            })
            .map(|(i, _)| i);
    }
    // rotate among near-ties of the minimum without allocating
    let min_u = loads[least].u;
    let min_q = loads[least].norm_queue();
    let tied = |l: &InstanceLoad| l.u - min_u < TIE_EPS && l.norm_queue() == min_q;
    let n_tied = loads.iter().filter(|l| tied(l)).count();
    let want = rr % n_tied;
    loads
        .iter()
        .enumerate()
        .filter(|(_, l)| tied(l))
        .nth(want)
        .map(|(i, _)| i)
}

/// Trait wrapper over [`pick_load_aware`] (BanaServe Alg 2).
#[derive(Debug)]
pub struct LoadAware {
    pub delta_l: f64,
    rr: usize,
}

impl LoadAware {
    pub fn new(delta_l: f64) -> Self {
        LoadAware { delta_l, rr: 0 }
    }
}

impl Router for LoadAware {
    fn pick(&mut self, loads: &[InstanceLoad]) -> Option<usize> {
        let p = pick_load_aware(loads, self.delta_l, self.rr);
        self.rr = self.rr.wrapping_add(1);
        p
    }

    fn name(&self) -> &'static str {
        "load-aware"
    }
}

// ---------------------------------------------------------------------------
// Scalable routing: tournament-tree index + power-of-two-choices sampling
// ---------------------------------------------------------------------------

/// Sentinel for "no eligible entry" in a tournament-tree slot.
pub const NONE_POS: usize = usize::MAX;

/// The comparison key a [`TournamentTree`] maintains its winner under. Each
/// key reproduces one scan router's exact comparison-and-tie-break order
/// over MAINTAINED book entries (where position == `idx`), so an indexed
/// pick is bit-identical to the linear scan over the eligible subset.
/// `CacheAware` has no key: its score depends on the request being routed
/// (per-request `cache_hit`) and cannot be maintained in an index — it
/// scales via sampling only.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TreeKey {
    /// Min (load_seqs/w, queue_len/w, idx) — [`LeastLoaded`].
    LeastLoaded,
    /// Min (queue_len/w, load_seqs/w, idx) — [`LeastQueue`].
    LeastQueue,
    /// Max (mem_free, fewest running/w), ties to the LAST candidate —
    /// [`MostFreeMem`].
    MostFreeMem,
    /// Min (u, queue_len/w, idx) — the primary pick of
    /// [`pick_load_aware`] (Alg 2). The tree winner of any subtree attains
    /// that subtree's minimum `u`, which is what makes the near-tie
    /// descent's pruning exact.
    LoadAwareU,
    /// Min (queue_len/w, u, idx) — Alg 2's overloaded-everywhere fallback.
    LoadAwareQ,
}

impl TreeKey {
    /// Does candidate `b` beat the incumbent `a` under this key? The final
    /// `idx` comparison reproduces the scan routers' tie-break exactly:
    /// min policies keep the FIRST (lowest-idx) minimum, `MostFreeMem`
    /// keeps the LAST maximum — so the result is a total order usable both
    /// structurally (tree merges, where `a` is always the lower-position
    /// side) and over unordered p2c candidate sets.
    pub fn prefer(self, a: &InstanceLoad, b: &InstanceLoad) -> bool {
        use std::cmp::Ordering::*;
        match self {
            TreeKey::LeastLoaded => {
                b.norm_load()
                    .total_cmp(&a.norm_load())
                    .then(b.norm_queue().total_cmp(&a.norm_queue()))
                    .then(b.idx.cmp(&a.idx))
                    == Less
            }
            TreeKey::LeastQueue => {
                b.norm_queue()
                    .total_cmp(&a.norm_queue())
                    .then(b.norm_load().total_cmp(&a.norm_load()))
                    .then(b.idx.cmp(&a.idx))
                    == Less
            }
            TreeKey::LoadAwareU => {
                b.u.total_cmp(&a.u)
                    .then(b.norm_queue().total_cmp(&a.norm_queue()))
                    .then(b.idx.cmp(&a.idx))
                    == Less
            }
            TreeKey::LoadAwareQ => {
                b.norm_queue()
                    .total_cmp(&a.norm_queue())
                    .then(b.u.total_cmp(&a.u))
                    .then(b.idx.cmp(&a.idx))
                    == Less
            }
            TreeKey::MostFreeMem => {
                match a
                    .mem_free
                    .cmp(&b.mem_free)
                    .then(b.norm_running().total_cmp(&a.norm_running()))
                {
                    Less => true,
                    Greater => false,
                    // exact tie: the LAST maximal candidate wins, as the
                    // scan's max_by does
                    Equal => b.idx > a.idx,
                }
            }
        }
    }
}

/// Merge two slot winners (positions or [`NONE_POS`]) under `key`.
#[inline]
fn tree_winner(key: TreeKey, a: usize, b: usize, loads: &[InstanceLoad]) -> usize {
    if a == NONE_POS {
        return b;
    }
    if b == NONE_POS {
        return a;
    }
    if key.prefer(&loads[a], &loads[b]) {
        b
    } else {
        a
    }
}

/// Segment-tree-style min/max index over a [`LoadBook`]'s maintained
/// entries: a 1-based implicit binary tree whose leaves hold eligible entry
/// positions (or [`NONE_POS`]) and whose internal nodes hold the winner of
/// their two children under [`TreeKey::prefer`]. Rebuild is O(n), a
/// point update bubbles to the root in O(log n), and the overall best sits
/// at the root — exact picks without the O(fleet) scan.
#[derive(Debug)]
pub struct TournamentTree {
    key: TreeKey,
    /// Power-of-two leaf count (>= entries); leaf `i` lives at `cap + i`.
    cap: usize,
    slots: Vec<usize>,
}

impl TournamentTree {
    pub fn new(key: TreeKey) -> Self {
        TournamentTree {
            key,
            cap: 0,
            slots: Vec::new(),
        }
    }

    pub fn key(&self) -> TreeKey {
        self.key
    }

    /// Rebuild from scratch over `loads` (leaf `i` eligible iff
    /// `eligible[i]`). O(n).
    pub fn rebuild(&mut self, loads: &[InstanceLoad], eligible: &[bool]) {
        let key = self.key;
        self.cap = loads.len().next_power_of_two().max(1);
        self.slots.clear();
        self.slots.resize(2 * self.cap, NONE_POS);
        for i in 0..loads.len() {
            if eligible[i] {
                self.slots[self.cap + i] = i;
            }
        }
        for node in (1..self.cap).rev() {
            let w = tree_winner(key, self.slots[2 * node], self.slots[2 * node + 1], loads);
            self.slots[node] = w;
        }
    }

    /// Re-key entry `pos` after its load (or eligibility) changed: reset
    /// its leaf and bubble the winner chain to the root. O(log n).
    pub fn update(&mut self, pos: usize, loads: &[InstanceLoad], eligible: &[bool]) {
        if pos >= self.cap {
            self.rebuild(loads, eligible);
            return;
        }
        let key = self.key;
        let mut node = self.cap + pos;
        self.slots[node] = if eligible[pos] { pos } else { NONE_POS };
        node /= 2;
        while node >= 1 {
            let w = tree_winner(key, self.slots[2 * node], self.slots[2 * node + 1], loads);
            self.slots[node] = w;
            node /= 2;
        }
    }

    /// The best eligible position, or None when the tree is empty.
    pub fn best(&self) -> Option<usize> {
        match self.slots.get(1) {
            Some(&w) if w != NONE_POS => Some(w),
            _ => None,
        }
    }

    /// Collect (in position order) every eligible leaf satisfying Alg 2's
    /// near-tie predicate `u - min_u < TIE_EPS && norm_queue == min_q`,
    /// pruning every subtree whose winner already sits outside the `u`
    /// band. Sound only on a [`TreeKey::LoadAwareU`] tree: that tree's
    /// subtree winner attains the subtree-minimum `u`, so
    /// `winner.u - min_u >= TIE_EPS` implies the same for every leaf below
    /// it (IEEE subtraction by a constant is monotone) — and the pruning
    /// expression is the SAME `x - min_u >= TIE_EPS` the scan evaluates,
    /// keeping the two bit-identical.
    fn collect_ties(
        &self,
        node: usize,
        loads: &[InstanceLoad],
        min_u: f64,
        min_q: f64,
        out: &mut Vec<usize>,
    ) {
        debug_assert_eq!(self.key, TreeKey::LoadAwareU);
        let Some(&w) = self.slots.get(node) else { return };
        if w == NONE_POS || loads[w].u - min_u >= TIE_EPS {
            return;
        }
        if node >= self.cap {
            if loads[w].norm_queue() == min_q {
                out.push(w);
            }
            return;
        }
        self.collect_ties(2 * node, loads, min_u, min_q, out);
        self.collect_ties(2 * node + 1, loads, min_u, min_q, out);
    }
}

/// The tournament-index state a [`LoadBook`] owns when indexing is enabled:
/// one tree per requested key, a shared eligibility mask, and the deferred
/// dirty set `set_queue`/`entry_mut` feed (flushed at the next pick).
#[derive(Debug)]
pub struct BookIndex {
    trees: Vec<TournamentTree>,
    eligible: Vec<bool>,
    dirty: Vec<usize>,
    dirty_mark: Vec<bool>,
    /// Reusable near-tie buffer for the indexed Alg 2 rotation.
    ties: Vec<usize>,
}

/// Power-of-two-choices candidate sampler: draws `k` DISTINCT eligible
/// positions from `[0, n)` on a dedicated PRNG substream derived from the
/// experiment seed ("route-p2c"), so enabling sampling never perturbs the
/// workload/fault streams — and leaving it off draws nothing, keeping
/// fixed-seed Reports byte-identical.
#[derive(Debug)]
pub struct RouteSampler {
    rng: Rng,
    scratch: Vec<usize>,
}

impl RouteSampler {
    pub fn new(seed: u64) -> Self {
        RouteSampler {
            rng: Rng::new(seed).substream("route-p2c"),
            scratch: Vec::new(),
        }
    }

    /// Sample up to `k` distinct eligible positions from `[0, n)`. Small
    /// fleets (`n <= k`) enumerate the eligible positions directly with
    /// ZERO draws; large fleets use bounded rejection sampling (sparse
    /// eligibility can return fewer than `k` — possibly zero — candidates,
    /// and callers fall back to their filtered scan then).
    pub fn sample(&mut self, n: usize, k: usize, mut eligible: impl FnMut(usize) -> bool) -> &[usize] {
        self.scratch.clear();
        if n == 0 || k == 0 {
            return &self.scratch;
        }
        if n <= k {
            for i in 0..n {
                if eligible(i) {
                    self.scratch.push(i);
                }
            }
            return &self.scratch;
        }
        let max_attempts = (8 * k).max(16);
        let mut attempts = 0;
        while self.scratch.len() < k && attempts < max_attempts {
            attempts += 1;
            let i = self.rng.below(n as u64) as usize;
            if eligible(i) && !self.scratch.contains(&i) {
                self.scratch.push(i);
            }
        }
        &self.scratch
    }
}

/// The p2c decision step: the best position among `candidates` under
/// `key`'s exact comparator (deterministic over unordered candidate sets —
/// [`TreeKey::prefer`] breaks exact ties by `idx`).
pub fn best_of(key: TreeKey, loads: &[InstanceLoad], candidates: &[usize]) -> Option<usize> {
    let mut best: Option<usize> = None;
    for &c in candidates {
        best = match best {
            Some(b) if !key.prefer(&loads[b], &loads[c]) => Some(b),
            _ => Some(c),
        };
    }
    best
}

// ---------------------------------------------------------------------------
// Typed timer dispatch
// ---------------------------------------------------------------------------

/// The typed form of every timer the engines schedule. `timer()` encodes
/// into the sim's `(tag, a, b)` wire format; `decode` inverts it. Worker
/// indices are engine-defined (e.g. BanaServe packs device·2 + role bit),
/// but the *kind* dispatch is now typed and shared.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetEvent {
    /// A compute step finished on worker `worker`. `token` is the
    /// worker's step token at schedule time: a crash teardown bumps the
    /// worker's token, so a StepDone from a torn-down step arrives with a
    /// stale token and is dropped instead of completing ghost work. With
    /// faults off tokens always match (the pre-fault wire format carried a
    /// constant 0 here — same (tag, a, b) layout, so event streams keep
    /// their exact (time, seq) order).
    StepDone { worker: usize, token: u64 },
    /// Staged/transferred KV of sequence `seq` arrived at worker `worker`.
    KvArrive { worker: usize, seq: u64 },
    /// Orchestrator control cycle.
    Control,
    /// Migration to device `device` completed (`kind`: 0 layer, 1 attention).
    MigrationDone { device: usize, kind: u64 },
    /// Elastic-fleet autoscale evaluation tick.
    Autoscale,
    /// Next due entry of the fault plan (crash/recover/straggler edges).
    Fault,
    /// Re-queue sequence `seq` after its crash-retry backoff expired.
    Requeue { seq: u64 },
    /// Transfer transaction `tx` completed (transfer plane only): the
    /// engine commits the transfer's effect and removes the transaction.
    XferDone { tx: u64 },
    /// Transfer transaction `tx` hit its deadline (timeout or partition):
    /// the engine rolls back and retries or falls back.
    XferAbort { tx: u64 },
}

impl FleetEvent {
    /// Encode into the raw timer wire format.
    pub fn timer(self) -> Timer {
        match self {
            FleetEvent::StepDone { worker, token } => {
                Timer::with(tags::STEP_DONE, worker as u64, token)
            }
            FleetEvent::KvArrive { worker, seq } => {
                Timer::with(tags::KV_ARRIVE, worker as u64, seq)
            }
            FleetEvent::Control => Timer::new(tags::CONTROL),
            FleetEvent::MigrationDone { device, kind } => {
                Timer::with(tags::MIG_DONE, device as u64, kind)
            }
            FleetEvent::Autoscale => Timer::new(tags::AUTOSCALE),
            FleetEvent::Fault => Timer::new(tags::FAULT),
            FleetEvent::Requeue { seq } => Timer::with(tags::REQUEUE, seq, 0),
            FleetEvent::XferDone { tx } => Timer::with(tags::XFER_DONE, tx, 0),
            FleetEvent::XferAbort { tx } => Timer::with(tags::XFER_ABORT, tx, 0),
        }
    }

    /// Decode a raw timer; None for unknown tags (engine bug).
    pub fn decode(t: Timer) -> Option<FleetEvent> {
        match t.tag {
            tags::STEP_DONE => Some(FleetEvent::StepDone {
                worker: t.a as usize,
                token: t.b,
            }),
            tags::KV_ARRIVE => Some(FleetEvent::KvArrive {
                worker: t.a as usize,
                seq: t.b,
            }),
            tags::CONTROL => Some(FleetEvent::Control),
            tags::MIG_DONE => Some(FleetEvent::MigrationDone {
                device: t.a as usize,
                kind: t.b,
            }),
            tags::AUTOSCALE => Some(FleetEvent::Autoscale),
            tags::FAULT => Some(FleetEvent::Fault),
            tags::REQUEUE => Some(FleetEvent::Requeue { seq: t.a }),
            tags::XFER_DONE => Some(FleetEvent::XferDone { tx: t.a }),
            tags::XFER_ABORT => Some(FleetEvent::XferAbort { tx: t.a }),
            _ => None,
        }
    }
}

// ---------------------------------------------------------------------------
// Elastic fleet: windowed-load autoscaler
// ---------------------------------------------------------------------------

/// Windowed load snapshot of one ACTIVE device, fed to the autoscaler.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FleetLoad {
    pub idx: usize,
    /// Busy fraction over the evaluation window, in [0, 1].
    pub busy: f64,
    /// Requests waiting at this device.
    pub queued: usize,
    /// Sequences resident (waiting + running, both roles).
    pub resident: usize,
    /// May this device be drained? (role constraints are the engine's call:
    /// e.g. never the last prefill-capable device, never mid-migration).
    pub drainable: bool,
    /// Cost rate of the backing device ([`GpuSpec::cost`]) — drives the
    /// cost-greedy drain victim choice. With a homogeneous fleet every
    /// cost ties and the selection falls through to the load tie-breaks,
    /// byte-identically to the pre-cost behavior.
    pub cost: f64,
}

/// How long a freshly scaled-out device stays "under watch" for the
/// post-scale-out TTFT report ([`crate::engines::EngineExtras::ttft_after_scaleout_s`]):
/// requests finishing on the device within this many seconds of it joining
/// the fleet contribute — the window where a cold KV cache hurts most.
pub const SCALEOUT_WATCH_SECS: f64 = 30.0;

/// What the autoscaler wants done this window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    /// Add one device.
    Out,
    /// Begin draining `victim`.
    In { victim: usize },
    Hold,
}

/// Windowed P99 observations fed to an SLO-mode decision (from the
/// engine's [`crate::metrics::SloTracker`]). `None` = no completions in
/// the retained windows, which the decision treats as "no evidence of a
/// breach" — queue pressure still covers the cold-start burst edge.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct SloView {
    pub p99_ttft: Option<f64>,
    pub p99_tpot: Option<f64>,
}

impl SloView {
    pub const NONE: SloView = SloView {
        p99_ttft: None,
        p99_tpot: None,
    };
}

/// The windowed autoscaling policy, in one of two modes:
///
/// * **SLO mode** (either `ttft_slo_ms` or `tpot_slo_ms` set): scale out
///   when the windowed P99 of any set target exceeds `slo_headroom` x
///   target (or on acute queue pressure — the burst edge fires before a
///   single completion can raise the P99); drain only when every set
///   target sits comfortably below half its headroom'd target AND the
///   fleet is idle by the util thresholds.
/// * **Util fallback** (no targets set — the PR 2 behavior, bit-identical):
///   scale out when mean busy exceeds `scale_out_util` or queues mount,
///   drain when it falls below `scale_in_util` with empty queues.
///
/// Both modes are bounded by min/max fleet size, never drain the last
/// active device, and are rate-limited by a cooldown so a single burst
/// edge can't thrash.
#[derive(Debug)]
pub struct Autoscaler {
    pub cfg: AutoscaleConfig,
    cooldown_until: f64,
    /// Calibrated per-device service rate (req/s at full busy), learned
    /// online from busy windows. Only the proactive path
    /// ([`Autoscaler::decide_proactive`]) reads or writes it; the reactive
    /// [`Autoscaler::decide`] never touches it, so forecast-off runs stay
    /// bit-identical.
    rate_per_device: Option<f64>,
}

impl Autoscaler {
    pub fn new(cfg: AutoscaleConfig) -> Self {
        Autoscaler {
            cfg,
            cooldown_until: 0.0,
            rate_per_device: None,
        }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// Is the decision SLO-driven (any P99 target set)?
    pub fn slo_mode(&self) -> bool {
        self.cfg.ttft_slo_ms > 0.0 || self.cfg.tpot_slo_ms > 0.0
    }

    /// Relative P99 overshoot above the most-violated set target, >= 0
    /// (0 in util mode or when every target is met) — the "SLO gap" that
    /// drives the scale-out spec choice ([`pick_scale_out_spec`]).
    pub fn slo_gap(&self, slo: SloView) -> f64 {
        let mut gap = 0.0_f64;
        if self.cfg.ttft_slo_ms > 0.0 {
            if let Some(p) = slo.p99_ttft {
                gap = gap.max(p / (self.cfg.ttft_slo_ms / 1e3) - 1.0);
            }
        }
        if self.cfg.tpot_slo_ms > 0.0 {
            if let Some(p) = slo.p99_tpot {
                gap = gap.max(p / (self.cfg.tpot_slo_ms / 1e3) - 1.0);
            }
        }
        gap.max(0.0)
    }

    /// One evaluation over the ACTIVE devices' windowed loads.
    /// `global_backlog` counts engine-wide queued work not attributable to
    /// one device (e.g. BanaServe's store-staged sequences awaiting decode
    /// admission); it joins the per-device `queued` sum for the
    /// queue-pressure trigger. `slo` carries the windowed P99 digests;
    /// pass [`SloView::NONE`] in util mode (with no targets set the view
    /// is ignored and the decision degrades to the util thresholds
    /// bit-identically — pinned by `tests/prop_fleet.rs`).
    pub fn decide(
        &mut self,
        now: f64,
        active: &[FleetLoad],
        global_backlog: usize,
        slo: SloView,
    ) -> ScaleDecision {
        if !self.cfg.enabled || active.is_empty() || now < self.cooldown_until {
            return ScaleDecision::Hold;
        }
        let n = active.len();
        let mean_busy = active.iter().map(|l| l.busy).sum::<f64>() / n as f64;
        let queued: usize =
            active.iter().map(|l| l.queued).sum::<usize>() + global_backlog;
        // the queue-pressure trigger catches a burst edge before a full
        // window of saturation (util mode) or a single slow completion
        // (SLO mode) can register — the P99 killer on bursty traces
        let (scale_out, scale_in) = if self.slo_mode() {
            let head = self.cfg.slo_headroom.clamp(1e-3, 10.0);
            let mut breach = false;
            let mut comfortable = true;
            if self.cfg.ttft_slo_ms > 0.0 {
                let target = head * self.cfg.ttft_slo_ms / 1e3;
                let p = slo.p99_ttft.unwrap_or(0.0);
                breach |= p > target;
                comfortable &= p < 0.5 * target;
            }
            if self.cfg.tpot_slo_ms > 0.0 {
                let target = head * self.cfg.tpot_slo_ms / 1e3;
                let p = slo.p99_tpot.unwrap_or(0.0);
                breach |= p > target;
                comfortable &= p < 0.5 * target;
            }
            (
                breach || queued > 4 * n,
                comfortable && mean_busy < self.cfg.scale_in_util && queued == 0,
            )
        } else {
            (
                mean_busy > self.cfg.scale_out_util || queued > 4 * n,
                mean_busy < self.cfg.scale_in_util && queued == 0,
            )
        };
        if n < self.cfg.max_devices && scale_out {
            self.cooldown_until = now + self.cfg.cooldown;
            return ScaleDecision::Out;
        }
        if n > self.cfg.min_devices && n > 1 && scale_in {
            // cost-greedy scale-in: once the fleet is comfortable enough to
            // shrink, release the MOST EXPENSIVE drainable device first
            // (with mixed specs the 80G should go before a 40G), ties
            // broken by load exactly as before — so a homogeneous fleet
            // drains its least-loaded device, bit-identically to PR 2
            if let Some(victim) = drain_victim(active) {
                self.cooldown_until = now + self.cfg.cooldown;
                return ScaleDecision::In { victim };
            }
        }
        ScaleDecision::Hold
    }

    /// The forecast-driven decision (`--forecast-mode proactive`). With no
    /// signal (forecaster still warming up, or the engine runs forecast-off)
    /// this delegates to [`Autoscaler::decide`] verbatim — same state, same
    /// cooldown, bit-identical decisions.
    ///
    /// With a signal, the decision order is:
    ///
    /// 1. **Calibrate**: whenever the fleet is measurably busy, fold the
    ///    observed `arrival rate / (busy × n)` into a per-device service
    ///    rate estimate (what one device absorbs at full utilization).
    /// 2. **Proactive scale-out**: the predicted peak rate over the
    ///    spin-up horizon exceeds `capacity × headroom` of the CURRENT
    ///    fleet — add a device before the spike lands, not after the P99
    ///    burns.
    /// 3. **Proactive scale-in**: even the predicted peak fits `n − 1`
    ///    devices inside the headroom with margin to spare (×0.7
    ///    hysteresis so out/in thresholds never chase each other) and
    ///    nothing is queued — shrink into the trough.
    /// 4. **Reactive backstop**: a live P99 breach or queue edge still
    ///    scales out through the reactive path (the forecaster can be
    ///    wrong); reactive DRAIN is suppressed once calibrated, so the
    ///    fleet never shrinks into a spike the forecaster already sees.
    ///
    /// All paths respect the same `[min, max]` bounds and the shared
    /// cooldown (pinned over arbitrary trajectories by
    /// `tests/prop_fleet.rs`).
    pub fn decide_proactive(
        &mut self,
        now: f64,
        active: &[FleetLoad],
        global_backlog: usize,
        slo: SloView,
        forecast: Option<ForecastSignal>,
    ) -> ScaleDecision {
        let Some(f) = forecast else {
            return self.decide(now, active, global_backlog, slo);
        };
        if !self.cfg.enabled || active.is_empty() || now < self.cooldown_until {
            return ScaleDecision::Hold;
        }
        let n = active.len();
        let mean_busy = active.iter().map(|l| l.busy).sum::<f64>() / n as f64;
        let queued: usize =
            active.iter().map(|l| l.queued).sum::<usize>() + global_backlog;
        if mean_busy > 0.2 && f.current_rate > 0.0 {
            let per = f.current_rate / (mean_busy * n as f64);
            self.rate_per_device = Some(match self.rate_per_device {
                Some(r) => 0.7 * r + 0.3 * per,
                None => per,
            });
        }
        if let Some(per) = self.rate_per_device {
            let head = f.headroom.clamp(1e-3, 1.0);
            if n < self.cfg.max_devices && f.predicted_rate > per * n as f64 * head {
                self.cooldown_until = now + self.cfg.cooldown;
                return ScaleDecision::Out;
            }
            if n > self.cfg.min_devices
                && n > 1
                && queued == 0
                && f.predicted_rate < per * (n - 1) as f64 * head * 0.7
            {
                if let Some(victim) = drain_victim(active) {
                    self.cooldown_until = now + self.cfg.cooldown;
                    return ScaleDecision::In { victim };
                }
            }
            // calibrated: the forecast owns scale-in; keep the reactive
            // breach/queue triggers as a scale-out backstop only (and give
            // the cooldown back when suppressing its drain — a decision
            // that didn't happen must not gate the next one)
            let saved = self.cooldown_until;
            return match self.decide(now, active, global_backlog, slo) {
                ScaleDecision::In { .. } => {
                    self.cooldown_until = saved;
                    ScaleDecision::Hold
                }
                d => d,
            };
        }
        // not yet calibrated: full reactive behavior
        self.decide(now, active, global_backlog, slo)
    }
}

/// Cost-greedy drain-victim choice shared by the reactive and proactive
/// paths: most expensive drainable device first, ties broken by (busy,
/// resident, idx) — so a homogeneous fleet drains its least-loaded device.
fn drain_victim(active: &[FleetLoad]) -> Option<usize> {
    active
        .iter()
        .filter(|l| l.drainable)
        .min_by(|a, b| {
            b.cost
                .total_cmp(&a.cost)
                .then(a.busy.total_cmp(&b.busy))
                .then(a.resident.cmp(&b.resident))
                .then(a.idx.cmp(&b.idx))
        })
        .map(|l| l.idx)
}

// ---------------------------------------------------------------------------
// Joint P/D pool sizing
// ---------------------------------------------------------------------------

/// Windowed prefill/decode demand accounting → joint pool-sizing hints for
/// the PD-disaggregated engines (coordinated autoscaling; see the
/// autoscaling-semantics notes in [`crate::engines`]).
///
/// Per-pool triggers thrash because prefill and decode demand move
/// together but at different ratios; instead the planner measures the
/// token mix (tokens of prefill work vs tokens of decode work per
/// decision window), smooths it, and answers ONE question for both pools:
/// given the target prefill share, which role should the next scale-out
/// join, and which pool should give up the next drain victim. Engines
/// consult it only in proactive forecast mode, so reactive runs keep
/// their historical role choices bit-identically.
#[derive(Debug, Default)]
pub struct PdPlanner {
    win_prefill: f64,
    win_decode: f64,
    share: Option<f64>,
}

impl PdPlanner {
    pub fn new() -> Self {
        Self::default()
    }

    /// Account `tokens` of prefill work (prompt tokens computed).
    pub fn record_prefill(&mut self, tokens: u64) {
        self.win_prefill += tokens as f64;
    }

    /// Account `tokens` of decode work (generation steps taken).
    pub fn record_decode(&mut self, tokens: u64) {
        self.win_decode += tokens as f64;
    }

    /// Close the demand window: fold the observed token mix into the
    /// smoothed prefill share (EWMA ½). An empty window keeps the prior
    /// estimate.
    pub fn roll(&mut self) {
        let total = self.win_prefill + self.win_decode;
        if total > 0.0 {
            let s = self.win_prefill / total;
            self.share = Some(match self.share {
                Some(prev) => 0.5 * prev + 0.5 * s,
                None => s,
            });
        }
        self.win_prefill = 0.0;
        self.win_decode = 0.0;
    }

    /// Smoothed prefill share of total demand, once any window closed with
    /// work in it.
    pub fn prefill_share(&self) -> Option<f64> {
        self.share
    }

    /// Target prefill-pool size for a fleet of `total` devices; both pools
    /// always keep at least one device. None below 2 devices or before any
    /// demand was measured.
    pub fn target_prefill(&self, total: usize) -> Option<usize> {
        if total < 2 {
            return None;
        }
        let s = self.share?;
        Some(((total as f64 * s).round() as usize).clamp(1, total - 1))
    }

    /// Should the next scale-out join the prefill pool? (Sizes the grown
    /// fleet jointly instead of firing per-pool triggers.)
    pub fn scale_out_to_prefill(&self, n_prefill: usize, n_decode: usize) -> Option<bool> {
        self.target_prefill(n_prefill + n_decode + 1)
            .map(|t| t > n_prefill)
    }

    /// Should the next drain victim come from the prefill pool?
    pub fn drain_from_prefill(&self, n_prefill: usize, n_decode: usize) -> Option<bool> {
        self.target_prefill(n_prefill + n_decode - 1)
            .map(|t| n_prefill > t)
    }
}

/// Price/perf spec choice for a scale-out: normally the cheapest capacity
/// wins (min cost/weight, ties to the lower absolute cost, then name);
/// when the SLO gap is large (windowed P99 >= 50% over target) the
/// HIGHEST-weight spec wins instead — raw capacity closes a deep gap
/// faster than another cheap device. Deterministic over any catalog order.
pub fn pick_scale_out_spec(catalog: &[GpuSpec], slo_gap: f64) -> Option<&GpuSpec> {
    if catalog.is_empty() {
        return None;
    }
    if slo_gap >= 0.5 {
        catalog.iter().min_by(|a, b| {
            b.weight
                .total_cmp(&a.weight)
                .then(a.cost.total_cmp(&b.cost))
                .then(a.name.cmp(b.name))
        })
    } else {
        catalog.iter().min_by(|a, b| {
            (a.cost / a.weight.max(1e-9))
                .total_cmp(&(b.cost / b.weight.max(1e-9)))
                .then(a.cost.total_cmp(&b.cost))
                .then(a.name.cmp(b.name))
        })
    }
}

/// Step-series bundle an elastic engine records at every fleet-membership
/// change (and at each decision window for `util`): total active size, the
/// active fleet's cost rate (Σ `GpuSpec::cost` over Active devices), and
/// per-spec active counts — the hetero-slo scenario's reporting surface.
#[derive(Debug, Default)]
pub struct FleetSeries {
    /// (time, active device count).
    pub size: TimeSeries,
    /// (time, windowed mean busy fraction) per decision window.
    pub util: TimeSeries,
    /// (time, Σ active device cost) — integrate for total device-cost.
    pub cost_rate: TimeSeries,
    /// (spec name, (time, active count) series), one entry per spec ever
    /// active in the fleet.
    pub by_spec: Vec<(&'static str, TimeSeries)>,
}

impl FleetSeries {
    pub fn new() -> Self {
        Self::default()
    }

    /// No membership sample recorded yet?
    pub fn is_empty(&self) -> bool {
        self.size.is_empty()
    }

    /// Record the current fleet composition at `now`. Size and per-spec
    /// counts cover ACTIVE devices (serving capacity); the cost rate bills
    /// every non-Released device — a Draining device still finishing its
    /// residents is still held (`cluster::try_release` refuses while KV is
    /// resident), so the elastic arm pays for its drain tails.
    pub fn sample(&mut self, now: f64, devices: &[Device]) {
        let mut total = 0usize;
        let mut cost = 0.0;
        for d in devices.iter() {
            if d.state != crate::cluster::DeviceState::Released {
                cost += d.spec.cost;
            }
            if d.is_active() {
                total += 1;
                if !self.by_spec.iter().any(|(n, _)| *n == d.spec.name) {
                    self.by_spec.push((d.spec.name, TimeSeries::new()));
                }
            }
        }
        self.size.push(now, total as f64);
        self.cost_rate.push(now, cost);
        for (name, ts) in self.by_spec.iter_mut() {
            let c = devices
                .iter()
                .filter(|d| d.is_active() && d.spec.name == *name)
                .count();
            ts.push(now, c as f64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::A100_40G;
    use crate::model::LLAMA_13B;

    fn mkreq(id: u64, prompt: u64, out: u64) -> Request {
        Request {
            id,
            arrival: 0.0,
            prompt_len: prompt,
            output_len: out,
            cache_tokens: vec![1, 2, 3].into(),
        }
    }

    #[test]
    fn seq_table_allocates_monotonic_ids_and_keeps_slots() {
        let mut t = SeqTable::new();
        let a = t.insert(Seq::new(mkreq(0, 8, 2)));
        let b = t.insert(Seq::new(mkreq(1, 8, 2)));
        assert_eq!((a, b), (0, 1));
        assert_eq!(t.len(), 2);
        assert!(t.get(a).is_some());
        t.remove(a);
        assert!(t.get(a).is_none(), "removed payload");
        assert_eq!(t.len(), 2, "slot index survives removal");
        let c = t.insert(Seq::new(mkreq(2, 8, 2)));
        assert_eq!(c, 2, "ids are never reused");
        t.seq_mut(b).generated = 1;
        assert_eq!(t.seq(b).generated, 1);
        assert_eq!(t.slots().len(), 3);
    }

    #[test]
    fn admission_drops_oversized_and_counts() {
        let mut col = Collector::new();
        let ok = mkreq(0, 100, 10);
        assert!(admit_or_drop(&LLAMA_13B, &A100_40G, &ok, &mut col));
        assert_eq!(col.dropped, 0);
        let huge = mkreq(1, 1_000_000, 512);
        assert!(!admit_or_drop(&LLAMA_13B, &A100_40G, &huge, &mut col));
        assert_eq!(col.dropped, 1);
    }

    #[test]
    fn fleet_event_roundtrips_over_timer_wire_format() {
        let evs = [
            FleetEvent::StepDone { worker: 7, token: 42 },
            FleetEvent::KvArrive { worker: 3, seq: 99 },
            FleetEvent::Control,
            FleetEvent::MigrationDone { device: 2, kind: 1 },
            FleetEvent::Autoscale,
            FleetEvent::Fault,
            FleetEvent::Requeue { seq: 12 },
            FleetEvent::XferDone { tx: 17 },
            FleetEvent::XferAbort { tx: 18 },
        ];
        for ev in evs {
            assert_eq!(FleetEvent::decode(ev.timer()), Some(ev));
        }
        assert_eq!(FleetEvent::decode(Timer::new(999)), None);
    }

    fn il(idx: usize, load: usize, q: usize) -> InstanceLoad {
        InstanceLoad {
            load_seqs: load,
            queue_len: q,
            ..InstanceLoad::at(idx)
        }
    }

    #[test]
    fn load_book_maintains_entries_and_reuses_scratch() {
        let mut b = LoadBook::with_instances(3);
        assert_eq!(b.len(), 3);
        b.set_queue(1, 4, 7);
        b.entry_mut(2).u = 0.5;
        assert_eq!(b.get(1).queue_len, 4);
        assert_eq!(b.loads()[1].load_seqs, 7);
        assert_eq!(b.loads()[2].u, 0.5);
        // filtered view preserves instance order and idx mapping
        let f = b.filtered(|l| l.queue_len > 0);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].idx, 1);
        // scale-out appends a zeroed stable-index entry
        assert_eq!(b.add_instance(), 3);
        assert_eq!(b.get(3), &InstanceLoad::at(3));
        // custom fill reuses the same scratch storage
        let s = b.fill();
        s.push(InstanceLoad::at(9));
        assert_eq!(b.scratch().len(), 1);
        assert_eq!(b.scratch()[0].idx, 9);
        assert!(b.fill().is_empty(), "fill must clear the scratch");
    }

    #[test]
    fn load_book_slice_routes_like_a_rebuilt_snapshot() {
        // the maintained slice and a freshly rebuilt snapshot must be
        // indistinguishable to every router
        let mut b = LoadBook::with_instances(4);
        for (i, (q, l)) in [(3, 5), (1, 2), (0, 0), (2, 9)].iter().enumerate() {
            b.set_queue(i, *q, *l);
        }
        let rebuilt: Vec<InstanceLoad> = (0..4)
            .map(|i| {
                let mut l = InstanceLoad::at(i);
                l.queue_len = b.get(i).queue_len;
                l.load_seqs = b.get(i).load_seqs;
                l
            })
            .collect();
        assert_eq!(b.loads(), &rebuilt[..]);
        assert_eq!(LeastLoaded.pick(b.loads()), LeastLoaded.pick(&rebuilt));
        assert_eq!(LeastQueue.pick(b.loads()), LeastQueue.pick(&rebuilt));
    }

    #[test]
    fn round_robin_cycles_and_least_loaded_prefers_min() {
        let loads = vec![il(0, 5, 0), il(1, 1, 0), il(2, 3, 0)];
        let mut rr = RoundRobin::default();
        let picks: Vec<usize> = (0..6).map(|_| rr.pick(&loads).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(LeastLoaded.pick(&loads), Some(1));
        assert_eq!(RoundRobin::default().pick(&[]), None);
    }

    #[test]
    fn least_queue_and_most_free_mem_match_distserve_picks() {
        let mut a = il(0, 9, 2);
        let mut b = il(1, 1, 4);
        a.mem_free = 100;
        a.running = 3;
        b.mem_free = 100;
        b.running = 1;
        let loads = vec![a, b];
        // distserve prefill: min (queue, load, idx)
        assert_eq!(LeastQueue.pick(&loads), Some(0));
        // distserve decode: max (mem_free, fewest running) -> b
        assert_eq!(MostFreeMem.pick(&loads), Some(1));
    }

    #[test]
    fn cache_aware_prefers_hits_until_load_dominates() {
        let mut hot = il(0, 8, 0);
        hot.cache_hit = 0.9;
        let cold = il(1, 1, 0);
        let mut r = CacheAware {
            w_cache: 1.0,
            w_load: 0.5,
        };
        // hit 0.9 - 0.5*1.0 = 0.4 beats 0 - 0.5*(1/8)
        assert_eq!(r.pick(&[hot, cold]), Some(0));
        let mut heavy = CacheAware {
            w_cache: 0.1,
            w_load: 2.0,
        };
        assert_eq!(heavy.pick(&[hot, cold]), Some(1), "load term must win");
    }

    #[test]
    fn load_aware_rotates_ties_like_alg2() {
        let loads: Vec<InstanceLoad> = (0..3)
            .map(|i| {
                let mut l = il(i, 0, 0);
                l.u = 0.3;
                l
            })
            .collect();
        let mut r = LoadAware::new(1.6);
        let picks: Vec<usize> = (0..6).map(|_| r.pick(&loads).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    /// A small book with varied counters for index/scan comparisons.
    fn varied_book(n: usize) -> LoadBook {
        let mut b = LoadBook::with_instances(n);
        for i in 0..n {
            b.set_queue(i, (i * 7 + 3) % 5, (i * 13 + 1) % 9);
            let e = b.entry_mut(i);
            e.u = ((i * 31 + 5) % 17) as f64 / 10.0;
            e.running = (i * 3) % 6;
            e.mem_free = ((i * 11 + 2) % 13) as u64 * 1_000;
        }
        b
    }

    #[test]
    fn tournament_index_matches_scan_for_each_key() {
        for n in [1usize, 2, 3, 7, 8, 9, 33] {
            let mut b = varied_book(n);
            b.enable_index(&[TreeKey::LeastLoaded, TreeKey::LeastQueue, TreeKey::MostFreeMem]);
            assert_eq!(b.pick_indexed(TreeKey::LeastLoaded), LeastLoaded.pick(b.loads()), "n={n}");
            assert_eq!(b.pick_indexed(TreeKey::LeastQueue), LeastQueue.pick(b.loads()), "n={n}");
            assert_eq!(b.pick_indexed(TreeKey::MostFreeMem), MostFreeMem.pick(b.loads()), "n={n}");
            // incremental update keeps them identical
            b.set_queue(n / 2, 0, 0);
            assert_eq!(b.pick_indexed(TreeKey::LeastLoaded), LeastLoaded.pick(b.loads()));
            assert_eq!(b.pick_indexed(TreeKey::LeastQueue), LeastQueue.pick(b.loads()));
        }
    }

    #[test]
    fn tournament_index_respects_eligibility_and_growth() {
        let mut b = varied_book(4);
        b.enable_index(&[TreeKey::LeastQueue]);
        let full = b.pick_indexed(TreeKey::LeastQueue).unwrap();
        b.set_eligible(full, false);
        let next = b.pick_indexed(TreeKey::LeastQueue).unwrap();
        assert_ne!(next, full, "ineligible winner must be excluded");
        // the indexed pick over the eligible subset equals the filtered scan
        let keep: Vec<InstanceLoad> =
            b.loads().iter().filter(|l| l.idx != full).copied().collect();
        assert_eq!(b.loads()[next].idx, keep[LeastQueue.pick(&keep).unwrap()].idx);
        // scale-out past the power-of-two capacity rebuilds transparently
        for _ in 0..8 {
            let i = b.add_instance();
            b.set_queue(i, 0, 0);
        }
        assert_eq!(
            b.pick_indexed(TreeKey::LeastQueue),
            LeastQueue.pick(&b.filtered(|l| l.idx != full).to_vec())
                .map(|p| if p >= full { p + 1 } else { p }),
        );
        // everything ineligible -> None
        for i in 0..b.len() {
            b.set_eligible(i, false);
        }
        assert_eq!(b.pick_indexed(TreeKey::LeastQueue), None);
    }

    #[test]
    fn indexed_load_aware_matches_scan_rotation() {
        let mut b = varied_book(9);
        b.enable_index(&[TreeKey::LoadAwareU, TreeKey::LoadAwareQ]);
        // force a near-tie band: three devices share the minimum-ish U
        for i in [1usize, 4, 7] {
            b.set_queue(i, 0, 0);
            b.entry_mut(i).u = 0.10 + 0.01 * (i % 2) as f64;
        }
        for rr in 0..12 {
            assert_eq!(
                b.pick_indexed_load_aware(1.6, rr),
                pick_load_aware(b.loads(), 1.6, rr),
                "rr={rr}"
            );
        }
        // overloaded everywhere: the LoadAwareQ fallback must agree too
        for i in 0..b.len() {
            b.entry_mut(i).u = 1.9 + 0.01 * i as f64;
        }
        for rr in 0..4 {
            assert_eq!(
                b.pick_indexed_load_aware(1.6, rr),
                pick_load_aware(b.loads(), 1.6, rr)
            );
        }
    }

    #[test]
    fn route_sampler_draws_distinct_eligible_candidates() {
        let mut s = RouteSampler::new(42);
        // n <= k enumerates eligible positions with zero draws
        assert_eq!(s.sample(3, 8, |_| true), &[0, 1, 2]);
        assert_eq!(s.sample(3, 8, |i| i != 1), &[0, 2]);
        // large n: k distinct positions
        let picks: Vec<usize> = s.sample(1000, 2, |_| true).to_vec();
        assert_eq!(picks.len(), 2);
        assert_ne!(picks[0], picks[1]);
        assert!(picks.iter().all(|&i| i < 1000));
        // an eligibility filter is always honored
        let evens: Vec<usize> = s.sample(1000, 2, |i| i % 2 == 0).to_vec();
        assert!(evens.iter().all(|&i| i % 2 == 0));
        // same seed -> same stream
        let mut a = RouteSampler::new(7);
        let mut c = RouteSampler::new(7);
        for _ in 0..16 {
            assert_eq!(a.sample(512, 2, |_| true).to_vec(), c.sample(512, 2, |_| true).to_vec());
        }
        // nothing eligible: bounded attempts, empty result
        assert!(s.sample(1000, 2, |_| false).is_empty());
    }

    #[test]
    fn best_of_matches_policy_comparators() {
        let b = varied_book(16);
        let all: Vec<usize> = (0..16).collect();
        assert_eq!(best_of(TreeKey::LeastLoaded, b.loads(), &all), LeastLoaded.pick(b.loads()));
        assert_eq!(best_of(TreeKey::LeastQueue, b.loads(), &all), LeastQueue.pick(b.loads()));
        assert_eq!(best_of(TreeKey::MostFreeMem, b.loads(), &all), MostFreeMem.pick(b.loads()));
        // candidate order must not matter
        let rev: Vec<usize> = (0..16).rev().collect();
        assert_eq!(
            best_of(TreeKey::LeastQueue, b.loads(), &rev),
            best_of(TreeKey::LeastQueue, b.loads(), &all)
        );
        assert_eq!(best_of(TreeKey::LeastLoaded, b.loads(), &[]), None);
    }

    fn fl(idx: usize, busy: f64, queued: usize, drainable: bool) -> FleetLoad {
        FleetLoad {
            idx,
            busy,
            queued,
            resident: queued,
            drainable,
            cost: 1.0,
        }
    }

    #[test]
    fn autoscaler_scales_out_on_util_and_on_queue_pressure() {
        let mut cfg = AutoscaleConfig::default();
        cfg.enabled = true;
        cfg.min_devices = 1;
        cfg.max_devices = 4;
        let mut a = Autoscaler::new(cfg);
        assert!(!a.slo_mode(), "no targets set: util fallback mode");
        // utilization trigger
        assert_eq!(
            a.decide(0.0, &[fl(0, 0.95, 0, true), fl(1, 0.9, 0, true)], 0, SloView::NONE),
            ScaleDecision::Out
        );
        // cooldown holds
        assert_eq!(
            a.decide(1.0, &[fl(0, 0.95, 0, true), fl(1, 0.9, 0, true)], 0, SloView::NONE),
            ScaleDecision::Hold
        );
        // queue-pressure trigger after cooldown
        assert_eq!(
            a.decide(10.0, &[fl(0, 0.2, 9, true), fl(1, 0.1, 4, true)], 0, SloView::NONE),
            ScaleDecision::Out
        );
        // engine-wide backlog alone can trigger too
        assert_eq!(
            a.decide(20.0, &[fl(0, 0.2, 0, true), fl(1, 0.1, 0, true)], 20, SloView::NONE),
            ScaleDecision::Out
        );
        // at max: hold
        let four: Vec<FleetLoad> = (0..4).map(|i| fl(i, 0.99, 9, true)).collect();
        assert_eq!(a.decide(30.0, &four, 0, SloView::NONE), ScaleDecision::Hold);
    }

    #[test]
    fn autoscaler_drains_least_loaded_drainable_above_min() {
        let mut cfg = AutoscaleConfig::default();
        cfg.enabled = true;
        cfg.min_devices = 2;
        cfg.max_devices = 6;
        let mut a = Autoscaler::new(cfg);
        let loads = [fl(0, 0.2, 0, false), fl(1, 0.05, 0, true), fl(2, 0.1, 0, true)];
        assert_eq!(
            a.decide(0.0, &loads, 0, SloView::NONE),
            ScaleDecision::In { victim: 1 }
        );
        // at min devices: hold even when idle
        let mut b = Autoscaler::new(cfg);
        assert_eq!(
            b.decide(0.0, &[fl(0, 0.0, 0, true), fl(1, 0.0, 0, true)], 0, SloView::NONE),
            ScaleDecision::Hold
        );
        // nothing drainable: hold
        let mut c = Autoscaler::new(cfg);
        assert_eq!(
            c.decide(
                0.0,
                &[fl(0, 0.0, 0, false), fl(1, 0.0, 0, false), fl(2, 0.0, 0, false)],
                0,
                SloView::NONE
            ),
            ScaleDecision::Hold
        );
        // a lone active device never drains, even with min_devices = 0
        let mut solo_cfg = cfg;
        solo_cfg.min_devices = 0;
        let mut d = Autoscaler::new(solo_cfg);
        assert_eq!(
            d.decide(0.0, &[fl(0, 0.0, 0, true)], 0, SloView::NONE),
            ScaleDecision::Hold
        );
    }

    #[test]
    fn drain_is_cost_greedy_with_mixed_specs() {
        let mut cfg = AutoscaleConfig::default();
        cfg.enabled = true;
        cfg.min_devices = 1;
        cfg.max_devices = 6;
        let mut a = Autoscaler::new(cfg);
        // the 80G (cost 1.5) is BUSIER than the idle 40Gs but still wins
        // the drain once the fleet is comfortable — cost beats load...
        let mut loads = [fl(0, 0.05, 0, true), fl(1, 0.2, 0, true), fl(2, 0.1, 0, true)];
        loads[1].cost = 1.5;
        assert_eq!(
            a.decide(0.0, &loads, 0, SloView::NONE),
            ScaleDecision::In { victim: 1 }
        );
        // ...but a non-drainable expensive device defers to the cheap ones,
        // which fall back to the least-loaded order
        let mut b = Autoscaler::new(cfg);
        loads[1].drainable = false;
        assert_eq!(
            b.decide(0.0, &loads, 0, SloView::NONE),
            ScaleDecision::In { victim: 0 }
        );
    }

    #[test]
    fn autoscaler_disabled_always_holds() {
        let mut a = Autoscaler::new(AutoscaleConfig::default());
        assert!(!a.enabled());
        assert_eq!(
            a.decide(0.0, &[fl(0, 1.0, 50, true)], 0, SloView::NONE),
            ScaleDecision::Hold
        );
    }

    #[test]
    fn slo_mode_scales_on_p99_breach_and_drains_when_comfortable() {
        let mut cfg = AutoscaleConfig::default();
        cfg.enabled = true;
        cfg.min_devices = 1;
        cfg.max_devices = 4;
        cfg.ttft_slo_ms = 1000.0;
        cfg.slo_headroom = 0.9;
        let mut a = Autoscaler::new(cfg);
        assert!(a.slo_mode());
        let calm = [fl(0, 0.5, 0, true), fl(1, 0.5, 0, true)];
        // P99 above 0.9 x 1s: scale out even though util is moderate
        let breach = SloView { p99_ttft: Some(1.2), p99_tpot: None };
        assert_eq!(a.decide(0.0, &calm, 0, breach), ScaleDecision::Out);
        assert!(a.slo_gap(breach) > 0.19 && a.slo_gap(breach) < 0.21);
        // P99 just under the headroom'd target but not comfortable: hold
        let near = SloView { p99_ttft: Some(0.6), p99_tpot: None };
        assert_eq!(a.decide(10.0, &calm, 0, near), ScaleDecision::Hold);
        // comfortably under target AND idle: drain
        let idle = [fl(0, 0.1, 0, true), fl(1, 0.05, 0, true)];
        let comfy = SloView { p99_ttft: Some(0.1), p99_tpot: None };
        assert!(matches!(
            a.decide(20.0, &idle, 0, comfy),
            ScaleDecision::In { .. }
        ));
        // queue pressure still scales out with no P99 evidence at all
        let mut b = Autoscaler::new(cfg);
        assert_eq!(
            b.decide(0.0, &[fl(0, 0.1, 9, true), fl(1, 0.1, 4, true)], 0, SloView::NONE),
            ScaleDecision::Out
        );
        // TPOT target breached alone also triggers
        let mut tcfg = cfg;
        tcfg.ttft_slo_ms = 0.0;
        tcfg.tpot_slo_ms = 50.0;
        let mut c = Autoscaler::new(tcfg);
        let slow_tpot = SloView { p99_ttft: None, p99_tpot: Some(0.08) };
        assert_eq!(c.decide(0.0, &calm, 0, slow_tpot), ScaleDecision::Out);
    }

    fn sig(cur: f64, pred: f64) -> Option<ForecastSignal> {
        Some(ForecastSignal {
            current_rate: cur,
            predicted_rate: pred,
            headroom: 0.75,
        })
    }

    #[test]
    fn proactive_autoscaler_scales_ahead_of_predicted_spike() {
        let mut cfg = AutoscaleConfig::default();
        cfg.enabled = true;
        cfg.min_devices = 1;
        cfg.max_devices = 4;
        let mut a = Autoscaler::new(cfg);
        let calm = [fl(0, 0.5, 0, true), fl(1, 0.5, 0, true)];
        // calibrates per-device rate = 10 / (0.5 * 2) = 10 req/s in the
        // same call; predicted 18 > 10 * 2 * 0.75 = 15 -> scale out BEFORE
        // any reactive trigger (busy is moderate, queues empty)
        assert_eq!(
            a.decide_proactive(0.0, &calm, 0, SloView::NONE, sig(10.0, 18.0)),
            ScaleDecision::Out
        );
        // the shared cooldown gates proactive decisions too
        assert_eq!(
            a.decide_proactive(1.0, &calm, 0, SloView::NONE, sig(10.0, 30.0)),
            ScaleDecision::Hold
        );
        // predicted demand fits the headroom'd capacity: hold
        assert_eq!(
            a.decide_proactive(10.0, &calm, 0, SloView::NONE, sig(10.0, 12.0)),
            ScaleDecision::Hold
        );
        // deep trough predicted: proactive scale-in picks the usual
        // cost-greedy victim (least busy at uniform cost)
        let idle = [fl(0, 0.05, 0, true), fl(1, 0.02, 0, true)];
        assert_eq!(
            a.decide_proactive(20.0, &idle, 0, SloView::NONE, sig(0.5, 0.6)),
            ScaleDecision::In { victim: 1 }
        );
    }

    #[test]
    fn proactive_suppresses_reactive_drain_and_keeps_the_backstop() {
        let mut cfg = AutoscaleConfig::default();
        cfg.enabled = true;
        cfg.min_devices = 1;
        cfg.max_devices = 4;
        let mut a = Autoscaler::new(cfg);
        let busy = [fl(0, 0.6, 0, true), fl(1, 0.6, 0, true)];
        // calibrate (per-device ~= 10) without triggering anything
        assert_eq!(
            a.decide_proactive(0.0, &busy, 0, SloView::NONE, sig(12.0, 12.0)),
            ScaleDecision::Hold
        );
        // fleet idle enough for a REACTIVE drain (mean busy < scale_in_util,
        // queues empty) but the forecast still predicts near-threshold
        // demand: the drain is suppressed — don't shrink into a spike
        let idle = [fl(0, 0.1, 0, true), fl(1, 0.1, 0, true)];
        assert_eq!(
            a.decide_proactive(10.0, &idle, 0, SloView::NONE, sig(2.0, 6.0)),
            ScaleDecision::Hold
        );
        // ...while a live queue edge still scales out through the backstop
        // even when the forecast sees nothing
        let pressed = [fl(0, 0.3, 9, true), fl(1, 0.3, 4, true)];
        assert_eq!(
            a.decide_proactive(20.0, &pressed, 0, SloView::NONE, sig(2.0, 2.0)),
            ScaleDecision::Out
        );
    }

    #[test]
    fn proactive_without_signal_matches_reactive_bit_for_bit() {
        let mut cfg = AutoscaleConfig::default();
        cfg.enabled = true;
        cfg.min_devices = 1;
        cfg.max_devices = 4;
        let trajectories: [(&[FleetLoad], usize); 4] = [
            (&[fl(0, 0.95, 0, true), fl(1, 0.9, 0, true)], 0),
            (&[fl(0, 0.2, 9, true), fl(1, 0.1, 4, true)], 0),
            (&[fl(0, 0.05, 0, true), fl(1, 0.1, 0, true)], 0),
            (&[fl(0, 0.5, 0, true)], 7),
        ];
        let mut a = Autoscaler::new(cfg);
        let mut b = Autoscaler::new(cfg);
        for (i, (loads, backlog)) in trajectories.iter().enumerate() {
            let now = 10.0 * i as f64;
            assert_eq!(
                a.decide_proactive(now, loads, *backlog, SloView::NONE, None),
                b.decide(now, loads, *backlog, SloView::NONE),
                "step {i}"
            );
        }
    }

    #[test]
    fn pd_planner_sizes_both_pools_from_the_token_mix() {
        let mut p = PdPlanner::new();
        assert_eq!(p.prefill_share(), None, "no demand measured yet");
        assert_eq!(p.scale_out_to_prefill(2, 2), None);
        assert_eq!(p.drain_from_prefill(2, 2), None);
        p.record_prefill(3000);
        p.record_decode(1000);
        p.roll();
        assert!((p.prefill_share().unwrap() - 0.75).abs() < 1e-12);
        assert_eq!(p.target_prefill(5), Some(4));
        // growing 2P/2D: the 5-device target wants 4 prefill -> join prefill
        assert_eq!(p.scale_out_to_prefill(2, 2), Some(true));
        // shrinking 3P/1D: the 3-device target wants 2 prefill -> prefill gives
        assert_eq!(p.drain_from_prefill(3, 1), Some(true));
        // a decode-heavy window folds in at EWMA 1/2 and flips the choice
        p.record_decode(4000);
        p.roll();
        assert!((p.prefill_share().unwrap() - 0.375).abs() < 1e-12);
        assert_eq!(p.scale_out_to_prefill(2, 2), Some(false));
        // an empty window keeps the prior estimate
        p.roll();
        assert!((p.prefill_share().unwrap() - 0.375).abs() < 1e-12);
        // both pools always keep at least one device
        let mut q = PdPlanner::new();
        q.record_prefill(100);
        q.roll();
        assert_eq!(q.prefill_share(), Some(1.0));
        assert_eq!(q.target_prefill(4), Some(3), "clamped below total");
        assert_eq!(q.drain_from_prefill(1, 3), Some(false));
        assert_eq!(q.target_prefill(1), None, "degenerate fleet: no hint");
        assert_eq!(q.drain_from_prefill(1, 1), None);
    }

    #[test]
    fn scale_out_spec_pick_is_price_perf_until_the_gap_is_deep() {
        use crate::cluster::{A100_40G, A100_80G};
        let catalog = [A100_40G, A100_80G];
        // cost/weight: 40G = 1.0, 80G = 1.5/1.3 ≈ 1.15 — small gap buys cheap
        assert_eq!(pick_scale_out_spec(&catalog, 0.0).unwrap().name, "a100-40g");
        assert_eq!(pick_scale_out_spec(&catalog, 0.3).unwrap().name, "a100-40g");
        // deep gap buys capacity
        assert_eq!(pick_scale_out_spec(&catalog, 0.5).unwrap().name, "a100-80g");
        assert_eq!(pick_scale_out_spec(&catalog, 2.0).unwrap().name, "a100-80g");
        // catalog order must not matter
        let rev = [A100_80G, A100_40G];
        assert_eq!(pick_scale_out_spec(&rev, 0.0).unwrap().name, "a100-40g");
        assert_eq!(pick_scale_out_spec(&rev, 1.0).unwrap().name, "a100-80g");
        assert!(pick_scale_out_spec(&[], 0.0).is_none());
    }

    #[test]
    fn weighted_routers_prefer_the_heavier_device_at_equal_counts() {
        let mut light = il(0, 4, 4);
        light.weight = 1.0;
        let mut heavy = il(1, 4, 4);
        heavy.weight = 2.0;
        let loads = [light, heavy];
        // same absolute counts, twice the capacity: heavy is less loaded
        assert_eq!(LeastLoaded.pick(&loads), Some(1));
        assert_eq!(LeastQueue.pick(&loads), Some(1));
        // uniform weights keep the historical idx tie-break
        let uniform = [il(0, 4, 4), il(1, 4, 4)];
        assert_eq!(LeastLoaded.pick(&uniform), Some(0));
    }

    #[test]
    fn fleet_series_samples_size_cost_and_per_spec_counts() {
        use crate::cluster::{A100_40G, A100_80G, Role};
        let mut devs = vec![
            Device::new(0, A100_40G, Role::Unified),
            Device::new(1, A100_40G, Role::Unified),
        ];
        let mut fs = FleetSeries::new();
        assert!(fs.is_empty());
        fs.sample(0.0, &devs);
        devs.push(Device::new(2, A100_80G, Role::Unified));
        fs.sample(5.0, &devs);
        crate::cluster::begin_drain(&mut devs, 0);
        fs.sample(9.0, &devs);
        assert!(crate::cluster::try_release(&mut devs, 0, true));
        fs.sample(11.0, &devs);
        assert_eq!(
            fs.size.points,
            vec![(0.0, 2.0), (5.0, 3.0), (9.0, 2.0), (11.0, 2.0)]
        );
        let cost = |t: usize| fs.cost_rate.points[t].1;
        assert!((cost(0) - 2.0).abs() < 1e-12);
        assert!((cost(1) - (2.0 + A100_80G.cost)).abs() < 1e-12);
        // a Draining device is still held, so it still bills...
        assert!((cost(2) - (2.0 + A100_80G.cost)).abs() < 1e-12);
        // ...and stops billing only once Released
        assert!((cost(3) - (1.0 + A100_80G.cost)).abs() < 1e-12);
        let by: Vec<&str> = fs.by_spec.iter().map(|(n, _)| *n).collect();
        assert_eq!(by, vec!["a100-40g", "a100-80g"]);
        let forty = &fs.by_spec[0].1;
        assert_eq!(forty.points.last(), Some(&(9.0, 1.0)));
        // the 80G series starts at its first appearance
        let eighty = &fs.by_spec[1].1;
        assert_eq!(eighty.points.first(), Some(&(5.0, 1.0)));
    }
}
