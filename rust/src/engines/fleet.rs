//! The shared fleet/dispatch layer carved out of the four engines.
//!
//! Every engine used to re-implement the same four pieces privately; they
//! now live here, behind one interface each:
//!
//! * [`SeqTable`] — the sequence table (`Vec<Option<Seq>>` + id
//!   allocation). Ids are assigned in admission order and never reused;
//!   a finished sequence's slot is emptied but keeps its index so in-flight
//!   timers referencing the id stay valid.
//! * [`Router`] — the pluggable routing interface over per-instance
//!   [`InstanceLoad`] snapshots, unifying vLLM's `RouterPolicy` scoring,
//!   BanaServe's Alg 2 `pick`/`pick_rotating`, and DistServe's pool picks.
//!   Each implementation preserves the exact comparison and tie-break
//!   order of the engine it was extracted from.
//! * [`FleetEvent`] — the typed timer-dispatch table replacing the
//!   hand-rolled `match t.tag` blocks. Encoding is lossless over
//!   [`crate::sim::Timer`]'s `(tag, a, b)` wire format, so refactored
//!   engines replay identical event streams.
//! * [`admit_or_drop`] — FCFS admission control (`request_fits`
//!   rejection + drop accounting), previously copy-pasted four times.
//!
//! On top of the shared layer sits the **elastic fleet**: a windowed-load
//! [`Autoscaler`] that turns per-device [`FleetLoad`] snapshots into
//! [`ScaleDecision`]s (scale-out / drain-one / hold) under min/max fleet
//! bounds and a cooldown. The engines own execution: adding worker state
//! for a new device, or draining and releasing a victim.

use super::common::{self, tags, Seq};
use crate::cluster::GpuSpec;
use crate::config::AutoscaleConfig;
use crate::metrics::Collector;
use crate::model::ModelSpec;
use crate::sim::Timer;
use crate::workload::Request;

// ---------------------------------------------------------------------------
// Sequence table
// ---------------------------------------------------------------------------

/// The fleet-wide sequence table. Owns every admitted [`Seq`]; engines
/// refer to sequences by the `u64` id this table allocates.
#[derive(Debug, Default)]
pub struct SeqTable {
    slots: Vec<Option<Seq>>,
}

impl SeqTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Admit a sequence; returns its id (= slot index, allocation order).
    pub fn insert(&mut self, seq: Seq) -> u64 {
        let sid = self.slots.len() as u64;
        self.slots.push(Some(seq));
        sid
    }

    /// Total slots ever allocated (live + finished).
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn get(&self, sid: u64) -> Option<&Seq> {
        self.slots.get(sid as usize).and_then(|s| s.as_ref())
    }

    pub fn get_mut(&mut self, sid: u64) -> Option<&mut Seq> {
        self.slots.get_mut(sid as usize).and_then(|s| s.as_mut())
    }

    /// Borrow a live sequence; panics if the id was never allocated or the
    /// sequence already finished (engine logic error).
    pub fn seq(&self, sid: u64) -> &Seq {
        self.slots[sid as usize].as_ref().expect("live seq")
    }

    pub fn seq_mut(&mut self, sid: u64) -> &mut Seq {
        self.slots[sid as usize].as_mut().expect("live seq")
    }

    /// Drop a finished sequence's payload; the slot index stays allocated.
    pub fn remove(&mut self, sid: u64) -> Option<Seq> {
        self.slots[sid as usize].take()
    }

    /// The raw slot view `plan_prefill`/`plan_decode` consume.
    pub fn slots(&self) -> &[Option<Seq>] {
        &self.slots
    }
}

// ---------------------------------------------------------------------------
// Admission
// ---------------------------------------------------------------------------

/// FCFS admission control shared by all engines: a request whose prompt +
/// full output can never fit one device's post-weight HBM is dropped (and
/// counted) instead of deadlocking the head of the queue.
///
/// Returns true when the request may be admitted.
pub fn admit_or_drop(
    spec: &ModelSpec,
    gpu: &GpuSpec,
    req: &Request,
    col: &mut Collector,
) -> bool {
    if common::request_fits(spec, gpu, req) {
        return true;
    }
    log::debug!(
        "dropping request {} (ctx {} + out {} exceeds device KV)",
        req.id,
        req.prompt_len,
        req.output_len
    );
    col.dropped += 1;
    false
}

// ---------------------------------------------------------------------------
// Routing
// ---------------------------------------------------------------------------

/// Snapshot of one routable instance, superset of what every router needs.
/// Engines fill the fields their policy consumes and zero the rest.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InstanceLoad {
    /// Engine-level instance/device index (what a pick maps back to).
    pub idx: usize,
    /// Waiting + running sequences.
    pub load_seqs: usize,
    /// Waiting-queue depth.
    pub queue_len: usize,
    /// Running-set size (decode placement).
    pub running: usize,
    /// Normalized utilization U ∈ [0, 2] (BanaServe Eq 37).
    pub u: f64,
    /// Fraction of the request's cacheable prefix resident at this
    /// instance's prefix cache (vLLM cache-aware scoring).
    pub cache_hit: f64,
    /// Free HBM bytes (DistServe decode placement).
    pub mem_free: u64,
}

impl InstanceLoad {
    /// A zeroed snapshot for `idx` — callers overwrite what they use.
    pub fn at(idx: usize) -> Self {
        InstanceLoad {
            idx,
            load_seqs: 0,
            queue_len: 0,
            running: 0,
            u: 0.0,
            cache_hit: 0.0,
            mem_free: 0,
        }
    }
}

/// A routing policy. `pick` returns the POSITION within `loads` of the
/// chosen instance (None when `loads` is empty); callers map back through
/// `loads[pos].idx`. Policies may keep state (round-robin cursors).
pub trait Router {
    fn pick(&mut self, loads: &[InstanceLoad]) -> Option<usize>;
    fn name(&self) -> &'static str;
}

// ---------------------------------------------------------------------------
// Incremental load tracking
// ---------------------------------------------------------------------------

/// Persistent per-engine load tracking: one [`InstanceLoad`] per routable
/// instance, kept up to date at admit / step / finish / drain transitions,
/// plus a reusable scratch buffer for filtered router views.
///
/// This replaces the per-arrival snapshot rebuild (a fresh
/// `Vec<InstanceLoad>` allocation + full refill on EVERY routed event) the
/// engines used to do. Two usage modes:
///
/// * **Maintained slice** — engines whose router consumes cheap counters
///   (queue depth, resident sequences) sync them via [`LoadBook::set_queue`]
///   at the few transition points that mutate them and hand
///   [`LoadBook::loads`] straight to [`Router::pick`]: zero per-arrival
///   work beyond the pick itself (vLLM, HFT).
/// * **Filtered scratch** — engines that route over a filtered or derived
///   view (BanaServe's Alg 2 over unfrozen prefill-capable devices,
///   DistServe's role pools) fill the reusable scratch via
///   [`LoadBook::filtered`] / [`LoadBook::fill`] instead of collecting a
///   fresh `Vec`: allocation-free after warm-up.
///
/// The equivalence property test in `tests/prop_engines.rs` pins a
/// maintained book against rebuilt-from-scratch snapshots across random
/// transition streams.
#[derive(Debug, Default)]
pub struct LoadBook {
    entries: Vec<InstanceLoad>,
    scratch: Vec<InstanceLoad>,
}

impl LoadBook {
    pub fn new() -> Self {
        Self::default()
    }

    /// A book over `n` instances, all zeroed.
    pub fn with_instances(n: usize) -> Self {
        LoadBook {
            entries: (0..n).map(InstanceLoad::at).collect(),
            scratch: Vec::new(),
        }
    }

    /// Append a zeroed entry for a new (scaled-out) instance; returns its
    /// index. Instance indices are stable — drained instances keep their
    /// entry (engines filter them out of router views).
    pub fn add_instance(&mut self) -> usize {
        let idx = self.entries.len();
        self.entries.push(InstanceLoad::at(idx));
        idx
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, i: usize) -> &InstanceLoad {
        &self.entries[i]
    }

    pub fn entry_mut(&mut self, i: usize) -> &mut InstanceLoad {
        &mut self.entries[i]
    }

    /// The maintained full slice, in instance order — what a filter-free
    /// router reads directly.
    pub fn loads(&self) -> &[InstanceLoad] {
        &self.entries
    }

    /// O(1) sync of the queue counters for instance `i` — the common
    /// admit/step/finish transition hook.
    pub fn set_queue(&mut self, i: usize, queue_len: usize, load_seqs: usize) {
        let e = &mut self.entries[i];
        e.queue_len = queue_len;
        e.load_seqs = load_seqs;
    }

    /// Fill the scratch buffer with the maintained entries passing `keep`
    /// and return it — the reusable filtered router view.
    pub fn filtered(&mut self, mut keep: impl FnMut(&InstanceLoad) -> bool) -> &[InstanceLoad] {
        self.scratch.clear();
        let (entries, scratch) = (&self.entries, &mut self.scratch);
        scratch.extend(entries.iter().filter(|&l| keep(l)).copied());
        scratch
    }

    /// Clear and hand out the scratch buffer for a custom fill (derived
    /// fields like BanaServe's windowed `U` or DistServe's live free-memory
    /// reads). Read the result back via [`LoadBook::scratch`].
    pub fn fill(&mut self) -> &mut Vec<InstanceLoad> {
        self.scratch.clear();
        &mut self.scratch
    }

    /// The scratch buffer as last filled.
    pub fn scratch(&self) -> &[InstanceLoad] {
        &self.scratch
    }
}

/// Strict round robin over the snapshot order.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    /// Allocation-free fast path: round robin needs only the instance
    /// count, so per-arrival hot paths skip building snapshots entirely.
    pub fn pick_n(&mut self, n: usize) -> Option<usize> {
        if n == 0 {
            return None;
        }
        let i = self.next % n;
        self.next += 1;
        Some(i)
    }
}

impl Router for RoundRobin {
    fn pick(&mut self, loads: &[InstanceLoad]) -> Option<usize> {
        self.pick_n(loads.len())
    }

    fn name(&self) -> &'static str {
        "round-robin"
    }
}

/// Min (load_seqs, queue_len, idx) — vLLM's `LeastLoaded`.
#[derive(Debug, Default)]
pub struct LeastLoaded;

impl Router for LeastLoaded {
    fn pick(&mut self, loads: &[InstanceLoad]) -> Option<usize> {
        loads
            .iter()
            .enumerate()
            .min_by_key(|(_, l)| (l.load_seqs, l.queue_len, l.idx))
            .map(|(p, _)| p)
    }

    fn name(&self) -> &'static str {
        "least-loaded"
    }
}

/// Min (queue_len, load_seqs, idx) — DistServe's prefill dispatch.
#[derive(Debug, Default)]
pub struct LeastQueue;

impl Router for LeastQueue {
    fn pick(&mut self, loads: &[InstanceLoad]) -> Option<usize> {
        loads
            .iter()
            .enumerate()
            .min_by_key(|(_, l)| (l.queue_len, l.load_seqs, l.idx))
            .map(|(p, _)| p)
    }

    fn name(&self) -> &'static str {
        "least-queue"
    }
}

/// Max (mem_free, fewest running) — DistServe's decode placement.
#[derive(Debug, Default)]
pub struct MostFreeMem;

impl Router for MostFreeMem {
    fn pick(&mut self, loads: &[InstanceLoad]) -> Option<usize> {
        loads
            .iter()
            .enumerate()
            .max_by_key(|(_, l)| (l.mem_free, std::cmp::Reverse(l.running)))
            .map(|(p, _)| p)
    }

    fn name(&self) -> &'static str {
        "most-free-mem"
    }
}

/// vLLM/SGLang's cache-aware scoring: `w_cache·hit − w_load·(load/max)`,
/// highest score wins — the policy whose positive-feedback skew Fig 2a
/// demonstrates. Ties resolve to the LAST maximal candidate, exactly as
/// the original `max_by` loop did.
#[derive(Debug)]
pub struct CacheAware {
    pub w_cache: f64,
    pub w_load: f64,
}

impl Router for CacheAware {
    fn pick(&mut self, loads: &[InstanceLoad]) -> Option<usize> {
        let max_load = loads
            .iter()
            .map(|l| l.load_seqs)
            .max()
            .unwrap_or(0)
            .max(1) as f64;
        let score = |l: &InstanceLoad| {
            self.w_cache * l.cache_hit - self.w_load * (l.load_seqs as f64 / max_load)
        };
        loads
            .iter()
            .enumerate()
            .max_by(|(_, a), (_, b)| score(a).total_cmp(&score(b)))
            .map(|(p, _)| p)
    }

    fn name(&self) -> &'static str {
        "cache-aware"
    }
}

/// BanaServe's Alg 2 load-aware pick with rotating tie-breaks, stateless
/// form: engines that route from `&self` contexts keep their own rotation
/// cursor and call this directly; [`LoadAware`] wraps it for the trait.
///
/// This is a faithful, allocation-free port of
/// `banaserve::scheduler::pick_rotating` onto fleet snapshots (the fleet
/// layer must not depend on an engine module); a parity property test in
/// `tests/prop_engines.rs` pins the two implementations together.
pub fn pick_load_aware(loads: &[InstanceLoad], delta_l: f64, rr: usize) -> Option<usize> {
    if loads.is_empty() {
        return None;
    }
    let least = loads
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| {
            a.u.total_cmp(&b.u)
                .then(a.queue_len.cmp(&b.queue_len))
                .then(a.idx.cmp(&b.idx))
        })
        .map(|(i, _)| i)
        .unwrap();
    if loads[least].u >= delta_l {
        // overloaded everywhere: lowest queue wins (Alg 2 line 17)
        return loads
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                a.queue_len
                    .cmp(&b.queue_len)
                    .then(a.u.total_cmp(&b.u))
                    .then(a.idx.cmp(&b.idx))
            })
            .map(|(i, _)| i);
    }
    // rotate among near-ties of the minimum without allocating
    const TIE_EPS: f64 = 0.05;
    let min_u = loads[least].u;
    let min_q = loads[least].queue_len;
    let tied = |l: &InstanceLoad| l.u - min_u < TIE_EPS && l.queue_len == min_q;
    let n_tied = loads.iter().filter(|l| tied(l)).count();
    let want = rr % n_tied;
    loads
        .iter()
        .enumerate()
        .filter(|(_, l)| tied(l))
        .nth(want)
        .map(|(i, _)| i)
}

/// Trait wrapper over [`pick_load_aware`] (BanaServe Alg 2).
#[derive(Debug)]
pub struct LoadAware {
    pub delta_l: f64,
    rr: usize,
}

impl LoadAware {
    pub fn new(delta_l: f64) -> Self {
        LoadAware { delta_l, rr: 0 }
    }
}

impl Router for LoadAware {
    fn pick(&mut self, loads: &[InstanceLoad]) -> Option<usize> {
        let p = pick_load_aware(loads, self.delta_l, self.rr);
        self.rr = self.rr.wrapping_add(1);
        p
    }

    fn name(&self) -> &'static str {
        "load-aware"
    }
}

// ---------------------------------------------------------------------------
// Typed timer dispatch
// ---------------------------------------------------------------------------

/// The typed form of every timer the engines schedule. `timer()` encodes
/// into the sim's `(tag, a, b)` wire format; `decode` inverts it. Worker
/// indices are engine-defined (e.g. BanaServe packs device·2 + role bit),
/// but the *kind* dispatch is now typed and shared.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetEvent {
    /// A compute step finished on worker `worker`.
    StepDone { worker: usize },
    /// Staged/transferred KV of sequence `seq` arrived at worker `worker`.
    KvArrive { worker: usize, seq: u64 },
    /// Orchestrator control cycle.
    Control,
    /// Migration to device `device` completed (`kind`: 0 layer, 1 attention).
    MigrationDone { device: usize, kind: u64 },
    /// Elastic-fleet autoscale evaluation tick.
    Autoscale,
}

impl FleetEvent {
    /// Encode into the raw timer wire format.
    pub fn timer(self) -> Timer {
        match self {
            FleetEvent::StepDone { worker } => {
                Timer::with(tags::STEP_DONE, worker as u64, 0)
            }
            FleetEvent::KvArrive { worker, seq } => {
                Timer::with(tags::KV_ARRIVE, worker as u64, seq)
            }
            FleetEvent::Control => Timer::new(tags::CONTROL),
            FleetEvent::MigrationDone { device, kind } => {
                Timer::with(tags::MIG_DONE, device as u64, kind)
            }
            FleetEvent::Autoscale => Timer::new(tags::AUTOSCALE),
        }
    }

    /// Decode a raw timer; None for unknown tags (engine bug).
    pub fn decode(t: Timer) -> Option<FleetEvent> {
        match t.tag {
            tags::STEP_DONE => Some(FleetEvent::StepDone {
                worker: t.a as usize,
            }),
            tags::KV_ARRIVE => Some(FleetEvent::KvArrive {
                worker: t.a as usize,
                seq: t.b,
            }),
            tags::CONTROL => Some(FleetEvent::Control),
            tags::MIG_DONE => Some(FleetEvent::MigrationDone {
                device: t.a as usize,
                kind: t.b,
            }),
            tags::AUTOSCALE => Some(FleetEvent::Autoscale),
            _ => None,
        }
    }
}

// ---------------------------------------------------------------------------
// Elastic fleet: windowed-load autoscaler
// ---------------------------------------------------------------------------

/// Windowed load snapshot of one ACTIVE device, fed to the autoscaler.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FleetLoad {
    pub idx: usize,
    /// Busy fraction over the evaluation window, in [0, 1].
    pub busy: f64,
    /// Requests waiting at this device.
    pub queued: usize,
    /// Sequences resident (waiting + running, both roles).
    pub resident: usize,
    /// May this device be drained? (role constraints are the engine's call:
    /// e.g. never the last prefill-capable device, never mid-migration).
    pub drainable: bool,
}

/// What the autoscaler wants done this window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    /// Add one device.
    Out,
    /// Begin draining `victim`.
    In { victim: usize },
    Hold,
}

/// The windowed-load autoscaling policy: scale out when the fleet's mean
/// busy fraction exceeds `scale_out_util` (or queueing pressure mounts),
/// drain the least-loaded drainable device when it falls below
/// `scale_in_util` with empty queues — all bounded by min/max fleet size
/// and rate-limited by a cooldown so a single burst edge can't thrash.
#[derive(Debug)]
pub struct Autoscaler {
    pub cfg: AutoscaleConfig,
    cooldown_until: f64,
}

impl Autoscaler {
    pub fn new(cfg: AutoscaleConfig) -> Self {
        Autoscaler {
            cfg,
            cooldown_until: 0.0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// One evaluation over the ACTIVE devices' windowed loads.
    /// `global_backlog` counts engine-wide queued work not attributable to
    /// one device (e.g. BanaServe's store-staged sequences awaiting decode
    /// admission); it joins the per-device `queued` sum for the
    /// queue-pressure trigger.
    pub fn decide(
        &mut self,
        now: f64,
        active: &[FleetLoad],
        global_backlog: usize,
    ) -> ScaleDecision {
        if !self.cfg.enabled || active.is_empty() || now < self.cooldown_until {
            return ScaleDecision::Hold;
        }
        let n = active.len();
        let mean_busy = active.iter().map(|l| l.busy).sum::<f64>() / n as f64;
        let queued: usize =
            active.iter().map(|l| l.queued).sum::<usize>() + global_backlog;
        // scale out on sustained utilization OR acute queue pressure — the
        // queue trigger is what catches a burst edge before a full window
        // of saturation accrues (the P99 killer on bursty traces)
        if n < self.cfg.max_devices
            && (mean_busy > self.cfg.scale_out_util || queued > 4 * n)
        {
            self.cooldown_until = now + self.cfg.cooldown;
            return ScaleDecision::Out;
        }
        if n > self.cfg.min_devices && mean_busy < self.cfg.scale_in_util && queued == 0 {
            let victim = active
                .iter()
                .filter(|l| l.drainable)
                .min_by(|a, b| {
                    a.busy
                        .total_cmp(&b.busy)
                        .then(a.resident.cmp(&b.resident))
                        .then(a.idx.cmp(&b.idx))
                })
                .map(|l| l.idx);
            if let Some(victim) = victim {
                self.cooldown_until = now + self.cfg.cooldown;
                return ScaleDecision::In { victim };
            }
        }
        ScaleDecision::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::A100_40G;
    use crate::model::LLAMA_13B;

    fn mkreq(id: u64, prompt: u64, out: u64) -> Request {
        Request {
            id,
            arrival: 0.0,
            prompt_len: prompt,
            output_len: out,
            cache_tokens: vec![1, 2, 3].into(),
        }
    }

    #[test]
    fn seq_table_allocates_monotonic_ids_and_keeps_slots() {
        let mut t = SeqTable::new();
        let a = t.insert(Seq::new(mkreq(0, 8, 2)));
        let b = t.insert(Seq::new(mkreq(1, 8, 2)));
        assert_eq!((a, b), (0, 1));
        assert_eq!(t.len(), 2);
        assert!(t.get(a).is_some());
        t.remove(a);
        assert!(t.get(a).is_none(), "removed payload");
        assert_eq!(t.len(), 2, "slot index survives removal");
        let c = t.insert(Seq::new(mkreq(2, 8, 2)));
        assert_eq!(c, 2, "ids are never reused");
        t.seq_mut(b).generated = 1;
        assert_eq!(t.seq(b).generated, 1);
        assert_eq!(t.slots().len(), 3);
    }

    #[test]
    fn admission_drops_oversized_and_counts() {
        let mut col = Collector::new();
        let ok = mkreq(0, 100, 10);
        assert!(admit_or_drop(&LLAMA_13B, &A100_40G, &ok, &mut col));
        assert_eq!(col.dropped, 0);
        let huge = mkreq(1, 1_000_000, 512);
        assert!(!admit_or_drop(&LLAMA_13B, &A100_40G, &huge, &mut col));
        assert_eq!(col.dropped, 1);
    }

    #[test]
    fn fleet_event_roundtrips_over_timer_wire_format() {
        let evs = [
            FleetEvent::StepDone { worker: 7 },
            FleetEvent::KvArrive { worker: 3, seq: 99 },
            FleetEvent::Control,
            FleetEvent::MigrationDone { device: 2, kind: 1 },
            FleetEvent::Autoscale,
        ];
        for ev in evs {
            assert_eq!(FleetEvent::decode(ev.timer()), Some(ev));
        }
        assert_eq!(FleetEvent::decode(Timer::new(999)), None);
    }

    fn il(idx: usize, load: usize, q: usize) -> InstanceLoad {
        InstanceLoad {
            load_seqs: load,
            queue_len: q,
            ..InstanceLoad::at(idx)
        }
    }

    #[test]
    fn load_book_maintains_entries_and_reuses_scratch() {
        let mut b = LoadBook::with_instances(3);
        assert_eq!(b.len(), 3);
        b.set_queue(1, 4, 7);
        b.entry_mut(2).u = 0.5;
        assert_eq!(b.get(1).queue_len, 4);
        assert_eq!(b.loads()[1].load_seqs, 7);
        assert_eq!(b.loads()[2].u, 0.5);
        // filtered view preserves instance order and idx mapping
        let f = b.filtered(|l| l.queue_len > 0);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].idx, 1);
        // scale-out appends a zeroed stable-index entry
        assert_eq!(b.add_instance(), 3);
        assert_eq!(b.get(3), &InstanceLoad::at(3));
        // custom fill reuses the same scratch storage
        let s = b.fill();
        s.push(InstanceLoad::at(9));
        assert_eq!(b.scratch().len(), 1);
        assert_eq!(b.scratch()[0].idx, 9);
        assert!(b.fill().is_empty(), "fill must clear the scratch");
    }

    #[test]
    fn load_book_slice_routes_like_a_rebuilt_snapshot() {
        // the maintained slice and a freshly rebuilt snapshot must be
        // indistinguishable to every router
        let mut b = LoadBook::with_instances(4);
        for (i, (q, l)) in [(3, 5), (1, 2), (0, 0), (2, 9)].iter().enumerate() {
            b.set_queue(i, *q, *l);
        }
        let rebuilt: Vec<InstanceLoad> = (0..4)
            .map(|i| {
                let mut l = InstanceLoad::at(i);
                l.queue_len = b.get(i).queue_len;
                l.load_seqs = b.get(i).load_seqs;
                l
            })
            .collect();
        assert_eq!(b.loads(), &rebuilt[..]);
        assert_eq!(LeastLoaded.pick(b.loads()), LeastLoaded.pick(&rebuilt));
        assert_eq!(LeastQueue.pick(b.loads()), LeastQueue.pick(&rebuilt));
    }

    #[test]
    fn round_robin_cycles_and_least_loaded_prefers_min() {
        let loads = vec![il(0, 5, 0), il(1, 1, 0), il(2, 3, 0)];
        let mut rr = RoundRobin::default();
        let picks: Vec<usize> = (0..6).map(|_| rr.pick(&loads).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(LeastLoaded.pick(&loads), Some(1));
        assert_eq!(RoundRobin::default().pick(&[]), None);
    }

    #[test]
    fn least_queue_and_most_free_mem_match_distserve_picks() {
        let mut a = il(0, 9, 2);
        let mut b = il(1, 1, 4);
        a.mem_free = 100;
        a.running = 3;
        b.mem_free = 100;
        b.running = 1;
        let loads = vec![a, b];
        // distserve prefill: min (queue, load, idx)
        assert_eq!(LeastQueue.pick(&loads), Some(0));
        // distserve decode: max (mem_free, fewest running) -> b
        assert_eq!(MostFreeMem.pick(&loads), Some(1));
    }

    #[test]
    fn cache_aware_prefers_hits_until_load_dominates() {
        let mut hot = il(0, 8, 0);
        hot.cache_hit = 0.9;
        let cold = il(1, 1, 0);
        let mut r = CacheAware {
            w_cache: 1.0,
            w_load: 0.5,
        };
        // hit 0.9 - 0.5*1.0 = 0.4 beats 0 - 0.5*(1/8)
        assert_eq!(r.pick(&[hot, cold]), Some(0));
        let mut heavy = CacheAware {
            w_cache: 0.1,
            w_load: 2.0,
        };
        assert_eq!(heavy.pick(&[hot, cold]), Some(1), "load term must win");
    }

    #[test]
    fn load_aware_rotates_ties_like_alg2() {
        let loads: Vec<InstanceLoad> = (0..3)
            .map(|i| {
                let mut l = il(i, 0, 0);
                l.u = 0.3;
                l
            })
            .collect();
        let mut r = LoadAware::new(1.6);
        let picks: Vec<usize> = (0..6).map(|_| r.pick(&loads).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    fn fl(idx: usize, busy: f64, queued: usize, drainable: bool) -> FleetLoad {
        FleetLoad {
            idx,
            busy,
            queued,
            resident: queued,
            drainable,
        }
    }

    #[test]
    fn autoscaler_scales_out_on_util_and_on_queue_pressure() {
        let mut cfg = AutoscaleConfig::default();
        cfg.enabled = true;
        cfg.min_devices = 1;
        cfg.max_devices = 4;
        let mut a = Autoscaler::new(cfg);
        // utilization trigger
        assert_eq!(
            a.decide(0.0, &[fl(0, 0.95, 0, true), fl(1, 0.9, 0, true)], 0),
            ScaleDecision::Out
        );
        // cooldown holds
        assert_eq!(
            a.decide(1.0, &[fl(0, 0.95, 0, true), fl(1, 0.9, 0, true)], 0),
            ScaleDecision::Hold
        );
        // queue-pressure trigger after cooldown
        assert_eq!(
            a.decide(10.0, &[fl(0, 0.2, 9, true), fl(1, 0.1, 4, true)], 0),
            ScaleDecision::Out
        );
        // engine-wide backlog alone can trigger too
        assert_eq!(
            a.decide(20.0, &[fl(0, 0.2, 0, true), fl(1, 0.1, 0, true)], 20),
            ScaleDecision::Out
        );
        // at max: hold
        let four: Vec<FleetLoad> = (0..4).map(|i| fl(i, 0.99, 9, true)).collect();
        assert_eq!(a.decide(30.0, &four, 0), ScaleDecision::Hold);
    }

    #[test]
    fn autoscaler_drains_least_loaded_drainable_above_min() {
        let mut cfg = AutoscaleConfig::default();
        cfg.enabled = true;
        cfg.min_devices = 2;
        cfg.max_devices = 6;
        let mut a = Autoscaler::new(cfg);
        let loads = [fl(0, 0.2, 0, false), fl(1, 0.05, 0, true), fl(2, 0.1, 0, true)];
        assert_eq!(a.decide(0.0, &loads, 0), ScaleDecision::In { victim: 1 });
        // at min devices: hold even when idle
        let mut b = Autoscaler::new(cfg);
        assert_eq!(
            b.decide(0.0, &[fl(0, 0.0, 0, true), fl(1, 0.0, 0, true)], 0),
            ScaleDecision::Hold
        );
        // nothing drainable: hold
        let mut c = Autoscaler::new(cfg);
        assert_eq!(
            c.decide(
                0.0,
                &[fl(0, 0.0, 0, false), fl(1, 0.0, 0, false), fl(2, 0.0, 0, false)],
                0
            ),
            ScaleDecision::Hold
        );
    }

    #[test]
    fn autoscaler_disabled_always_holds() {
        let mut a = Autoscaler::new(AutoscaleConfig::default());
        assert!(!a.enabled());
        assert_eq!(a.decide(0.0, &[fl(0, 1.0, 50, true)], 0), ScaleDecision::Hold);
    }
}
