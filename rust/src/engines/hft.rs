//! HuggingFace-Transformers-like static batching baseline (Fig 1).
//!
//! The defining inefficiencies modeled:
//! * **static batches**: a batch is formed FCFS when the instance goes
//!   idle; nothing joins a running batch (no continuous batching);
//! * **padding**: every sequence in the batch is computed at the padded
//!   prompt length, and every decode step computes the full batch until the
//!   *longest* output finishes (finished rows keep burning compute);
//! * **no paging**: KV is reserved up front at padded prompt + max output
//!   for every slot.

use super::common::{self, InstanceSim, Seq, SeqPhase, StepInfo, StepKind};
use super::fleet::{self, FleetEvent, Router};
use super::xfer::{self, TxTable};
use crate::cluster::{self, Cluster, Device, DeviceState, GpuSpec, Link, LinkHealth, Role};
use crate::config::{ExperimentConfig, FaultConfig, RouteMode};
use crate::fault::{self, FaultEvent, FaultKind, FaultPlan, FaultTimeline};
use crate::metrics::{Collector, SloTracker};
use crate::perfmodel::{self, Efficiency, PrefillItem};
use crate::model::ModelSpec;
use crate::sim::{Engine, EventQueue, Timer};
use crate::workload::Request;

/// A running static batch on one instance.
#[derive(Debug, Clone)]
struct StaticBatch {
    seqs: Vec<u64>,
    padded_prompt: u64,
    max_output: u64,
    steps_done: u64,
    /// Reserved KV bytes per slot (padded, freed only at batch end).
    slot_kv: u64,
}

/// Static-batching engine over N unified devices, round-robin routed.
///
/// With `ExperimentConfig::autoscale` enabled the fleet is *elastic* on
/// the same AUTOSCALE tick as the other engines: scale-out appends a
/// unified instance (catalog spec by price/perf) behind a weight spin-up
/// freeze; scale-in drains an instance — round robin skips it, its queue
/// re-routes, the running batch finishes, then the device is released.
pub struct HftEngine {
    spec: &'static ModelSpec,
    eff: Efficiency,
    max_batch: u64,
    link: Link,
    pub devices: Vec<Device>,
    pub insts: Vec<InstanceSim>,
    batches: Vec<Option<StaticBatch>>,
    seqs: fleet::SeqTable,
    col: Collector,
    inflight: u64,
    router: fleet::RoundRobin,
    /// Maintained per-instance loads (round robin ignores the values, but
    /// the maintained slice lets load-aware policies drop in unchanged).
    book: fleet::LoadBook,
    /// Resolved routing mode: static round robin is already O(1), so only
    /// the elastic filtered path has a p2c fast path here.
    route_mode: RouteMode,
    /// p2c sample width (k).
    sample_k: usize,
    /// Dedicated `"route-p2c"` PRNG substream — zero draws unless p2c runs.
    sampler: fleet::RouteSampler,
    /// Specs the autoscaler may scale out with (price/perf choice).
    catalog: Vec<GpuSpec>,
    autoscaler: fleet::Autoscaler,
    /// Windowed P99-TTFT/TPOT digests fed from completion events (SLO mode).
    slo: SloTracker,
    as_last_busy: Vec<f64>,
    as_last_eval: f64,
    autoscale_ticking: bool,
    fleet_loads_buf: Vec<fleet::FleetLoad>,
    stranded_buf: Vec<u64>,
    pub fleet: fleet::FleetSeries,
    pub scale_outs: u64,
    pub drains: u64,
    fault_cfg: FaultConfig,
    faults: FaultTimeline,
    /// Per-device link health (transfer plane); default = healthy.
    linkh: Vec<LinkHealth>,
    /// In-flight spin-up transactions (empty while the plane is off).
    txs: TxTable<xfer::SpinUp>,
    /// Forecast subsystem; `None` with `--forecast-mode off` — the
    /// reactive path then never sees a signal and stays bit-identical.
    forecaster: Option<crate::forecast::RateForecaster>,
    /// When each device joined via scale-out (None = initial fleet);
    /// drives the post-scale-out TTFT watch window.
    joined_at: Vec<Option<f64>>,
    /// (Σ TTFT, n) over requests finishing on a scaled-out device inside
    /// its watch window ([`fleet::SCALEOUT_WATCH_SECS`]).
    post_scaleout_ttft: (f64, u64),
}

impl HftEngine {
    pub fn new(cfg: &ExperimentConfig) -> Self {
        let cluster = Cluster::homogeneous(cfg.n_devices, cfg.gpu.clone(), Role::Unified);
        let link = cluster.gpu_link;
        let mut devices = cluster.devices;
        for d in devices.iter_mut() {
            d.weight_bytes = cfg.model.weight_bytes();
        }
        let insts = (0..cfg.n_devices).map(|i| InstanceSim::new(i, 1.0)).collect();
        let mut book = fleet::LoadBook::with_instances(cfg.n_devices);
        for i in 0..cfg.n_devices {
            book.entry_mut(i).weight = devices[i].spec.weight;
        }
        let mut col = Collector::new();
        col.window_start = cfg.warmup;
        HftEngine {
            spec: cfg.model,
            eff: cfg.eff,
            max_batch: cfg.max_batch_seqs.min(16), // HFT typical small batches
            link,
            devices,
            insts,
            batches: (0..cfg.n_devices).map(|_| None).collect(),
            seqs: fleet::SeqTable::new(),
            col,
            inflight: 0,
            router: fleet::RoundRobin::default(),
            book,
            route_mode: cfg.routing.resolve(cfg.n_devices),
            sample_k: cfg.routing.sample_k.max(1),
            sampler: fleet::RouteSampler::new(cfg.workload.seed),
            catalog: if cfg.gpu_catalog.is_empty() {
                vec![cfg.gpu.clone()]
            } else {
                cfg.gpu_catalog.clone()
            },
            autoscaler: fleet::Autoscaler::new(cfg.autoscale),
            slo: SloTracker::new(cfg.autoscale.window),
            as_last_busy: vec![0.0; cfg.n_devices],
            as_last_eval: 0.0,
            autoscale_ticking: false,
            fleet_loads_buf: Vec::new(),
            stranded_buf: Vec::new(),
            fleet: fleet::FleetSeries::new(),
            scale_outs: 0,
            drains: 0,
            fault_cfg: cfg.fault,
            faults: FaultTimeline::new(FaultPlan::generate(
                &cfg.fault,
                cfg.workload.seed,
                cfg.n_devices,
                cfg.workload.duration,
            )),
            linkh: vec![LinkHealth::default(); cfg.n_devices],
            txs: TxTable::default(),
            forecaster: if crate::forecast::enabled(&cfg.forecast) {
                Some(crate::forecast::RateForecaster::new(
                    &cfg.forecast,
                    crate::forecast::resolve_period(&cfg.forecast, &cfg.workload.arrivals),
                ))
            } else {
                None
            },
            joined_at: vec![None; cfg.n_devices],
            post_scaleout_ttft: (0.0, 0),
        }
    }

    /// Route one arrival: static fleets keep the plain round robin over
    /// the maintained slice; elastic fleets round-robin over the filtered
    /// ACTIVE/unfrozen view (falling back to any active instance while
    /// every one is still spinning up).
    fn route(&mut self, now: f64) -> usize {
        if self.autoscaler.enabled() || self.faults.enabled() {
            // p2c fast path: round robin over a filtered view is O(fleet)
            // per arrival; sampling k active unfrozen candidates and
            // least-loading among them keeps elastic HFT O(1) too
            if self.route_mode == RouteMode::P2c {
                let n = self.insts.len();
                let k = self.sample_k;
                let (insts, devices) = (&self.insts, &self.devices);
                let cands = self.sampler.sample(n, k, |i| {
                    devices[insts[i].device].is_active() && now >= insts[i].frozen_until
                });
                if let Some(i) =
                    fleet::best_of(fleet::TreeKey::LeastLoaded, self.book.loads(), cands)
                {
                    return i;
                }
            }
            {
                let (book, insts, devices) = (&mut self.book, &self.insts, &self.devices);
                let loads = book.filtered(|l| {
                    devices[insts[l.idx].device].is_active()
                        && now >= insts[l.idx].frozen_until
                });
                if let Some(pos) = self.router.pick(loads) {
                    return loads[pos].idx;
                }
            }
            let (book, insts, devices) = (&mut self.book, &self.insts, &self.devices);
            let loads = book.filtered(|l| devices[insts[l.idx].device].is_active());
            return match self.router.pick(loads) {
                Some(pos) => loads[pos].idx,
                // unreachable while drain guards keep one active device
                None => 0,
            };
        }
        self.router.pick(self.book.loads()).expect("non-empty fleet")
    }

    /// Finish one sequence (record + counters); feeds the SLO tracker.
    fn finish_seq(&mut self, sid: u64, now: f64) {
        let seq = self.seqs.seq_mut(sid);
        seq.phase = SeqPhase::Finished;
        let inst = seq.instance;
        let rec = seq.record(now);
        if self.autoscaler.enabled() {
            self.slo.record(now, rec.ttft(), rec.tpot());
        }
        if let Some(j) = self.joined_at[self.insts[inst].device] {
            if now <= j + fleet::SCALEOUT_WATCH_SECS {
                self.post_scaleout_ttft.0 += rec.ttft();
                self.post_scaleout_ttft.1 += 1;
            }
        }
        self.col.finish(rec);
        self.inflight -= 1;
    }

    /// Try to start a batch on instance `i`, then sync its load-book entry
    /// (every waiting-queue mutation ends in this call).
    fn maybe_start(&mut self, i: usize, q: &mut EventQueue) {
        self.maybe_start_inner(i, q);
        let (ql, ls) = (self.insts[i].queue_len(), self.insts[i].load_seqs());
        self.book.set_queue(i, ql, ls);
    }

    fn maybe_start_inner(&mut self, i: usize, q: &mut EventQueue) {
        let now = q.now();
        if self.insts[i].is_busy()
            || self.batches[i].is_some()
            || now < self.insts[i].frozen_until
        {
            return;
        }
        if self.insts[i].waiting.is_empty() {
            return;
        }
        // form a static batch FCFS under the memory reservation constraint
        let dev_idx = self.insts[i].device;
        let mut chosen: Vec<u64> = Vec::new();
        let mut padded_prompt = 0u64;
        let mut max_output = 0u64;
        loop {
            let Some(&sid) = self.insts[i].waiting.front() else { break };
            if chosen.len() as u64 >= self.max_batch {
                break;
            }
            let s = self.seqs.seq(sid);
            let new_pad = padded_prompt.max(s.req.prompt_len);
            let new_out = max_output.max(s.req.output_len);
            let slot_kv = common::kv_bytes(self.spec, new_pad + new_out);
            let need = slot_kv * (chosen.len() as u64 + 1);
            if need > self.devices[dev_idx].mem_free() && !chosen.is_empty() {
                break;
            }
            self.insts[i].waiting.pop_front();
            chosen.push(sid);
            padded_prompt = new_pad;
            max_output = new_out;
        }
        if chosen.is_empty() {
            return;
        }
        let slot_kv = common::kv_bytes(self.spec, padded_prompt + max_output);
        let reserve = slot_kv * chosen.len() as u64;
        let reserve = reserve.min(self.devices[dev_idx].mem_free()); // clamp (head-of-line oversize)
        self.devices[dev_idx].alloc_kv(now, reserve);
        // padded prefill: every row computed at padded_prompt
        let items: Vec<PrefillItem> = chosen
            .iter()
            .map(|_| PrefillItem {
                prompt: padded_prompt,
                cached: 0,
            })
            .collect();
        for &sid in &chosen {
            let seq = self.seqs.seq_mut(sid);
            seq.phase = SeqPhase::Prefilling;
            seq.prefill_start = now;
            if seq.crashed_at >= 0.0 {
                let crashed_at = seq.crashed_at;
                seq.crashed_at = -1.0;
                self.faults.stats.on_recovered_seq(now, crashed_at);
            }
        }
        let st = perfmodel::prefill_step(
            self.spec,
            &self.devices[dev_idx].spec,
            &self.eff,
            &items,
            1.0,
        );
        common::mark_step_start(&mut self.devices[dev_idx], &mut self.insts[i], now, &st);
        // the batch owns the seq ids; HFT's step_done iterates the batch's
        // own list, so the StepInfo carries none — no Vec clone per batch
        let slot_kv = reserve / chosen.len().max(1) as u64;
        self.batches[i] = Some(StaticBatch {
            seqs: chosen,
            padded_prompt,
            max_output,
            steps_done: 0,
            slot_kv,
        });
        let overhead = self.devices[dev_idx].straggle_overhead(st.time);
        self.insts[i].step = Some(StepInfo {
            kind: StepKind::Prefill,
            seqs: Vec::new(),
            st,
            overhead,
        });
        self.insts[i].step_token += 1;
        let token = self.insts[i].step_token;
        q.push_after(
            st.time + overhead,
            FleetEvent::StepDone { worker: i, token }.timer(),
        );
    }

    fn step_done(&mut self, i: usize, token: u64, q: &mut EventQueue) {
        if token != self.insts[i].step_token {
            return; // stale timer from a batch torn down by a crash
        }
        let now = q.now();
        let step = self.insts[i].step.take().expect("step");
        let dev_idx = self.insts[i].device;
        common::mark_step_end(
            &mut self.devices[dev_idx],
            &mut self.insts[i],
            now,
            step.st.time + step.overhead,
            &step.st,
        );
        let mut batch = self.batches[i].take().expect("batch");
        match step.kind {
            StepKind::Prefill => {
                for &sid in &batch.seqs {
                    let done = {
                        let seq = self.seqs.seq_mut(sid);
                        seq.ctx = batch.padded_prompt + 1;
                        seq.generated = 1;
                        seq.first_token = now;
                        seq.phase = SeqPhase::Decoding;
                        seq.is_done()
                    };
                    if done {
                        self.finish_seq(sid, now);
                    }
                }
                batch.steps_done = 1;
            }
            StepKind::StaticDecode | StepKind::Decode => {
                batch.steps_done += 1;
                for &sid in &batch.seqs {
                    let done = {
                        let Some(seq) = self.seqs.get_mut(sid) else {
                            continue;
                        };
                        if seq.phase != SeqPhase::Decoding {
                            continue;
                        }
                        seq.generated += 1;
                        seq.ctx += 1;
                        seq.is_done()
                    };
                    if done {
                        self.finish_seq(sid, now);
                    }
                }
            }
        }
        if batch.steps_done < batch.max_output {
            // lock-step decode over the FULL batch (padding waste): context
            // grows at the padded length for every slot.
            let bsz = batch.seqs.len() as u64;
            let total_ctx = bsz * (batch.padded_prompt + batch.steps_done);
            let st = perfmodel::decode_step(
                self.spec,
                &self.devices[dev_idx].spec,
                &self.eff,
                bsz,
                total_ctx,
                1.0,
            );
            common::mark_step_start(&mut self.devices[dev_idx], &mut self.insts[i], now, &st);
            let overhead = self.devices[dev_idx].straggle_overhead(st.time);
            self.insts[i].step = Some(StepInfo {
                kind: StepKind::StaticDecode,
                seqs: Vec::new(), // the batch owns the ids (see maybe_start)
                st,
                overhead,
            });
            self.batches[i] = Some(batch);
            self.insts[i].step_token += 1;
            let token = self.insts[i].step_token;
            q.push_after(
                self.insts[i].step.as_ref().unwrap().st.time + overhead,
                FleetEvent::StepDone { worker: i, token }.timer(),
            );
        } else {
            // batch complete: release the reservation, drop seq payloads
            let reserve = batch.slot_kv * batch.seqs.len() as u64;
            self.devices[dev_idx].free_kv(now, reserve);
            for &sid in &batch.seqs {
                self.seqs.remove(sid);
            }
            self.maybe_start(i, q);
            // a Draining device's last batch completion is its release
            // point — the autoscale tick alone would strand it when the
            // tick loop stops at inflight 0
            if self.autoscaler.enabled()
                && self.devices[dev_idx].state == DeviceState::Draining
            {
                self.finish_drains(now);
            }
        }
    }

    // --- fault injection ---------------------------------------------------

    /// Apply every due fault event, then keep exactly one Fault timer
    /// armed while events remain and work is in flight (arrivals re-arm).
    fn service_faults(&mut self, q: &mut EventQueue) {
        let now = q.now();
        while let Some(ev) = self.faults.pop_due(now) {
            self.apply_fault(ev, q);
        }
        if !self.faults.armed && self.inflight > 0 {
            if let Some(t) = self.faults.next_time() {
                self.faults.armed = true;
                q.push_timer(t.max(now), FleetEvent::Fault.timer());
            }
        }
    }

    fn apply_fault(&mut self, ev: FaultEvent, q: &mut EventQueue) {
        let now = q.now();
        match ev.kind {
            FaultKind::Crash => {
                let active = crate::cluster::active_count(&self.devices);
                if active <= 1 || !crate::cluster::fail_device(&mut self.devices, ev.device) {
                    return;
                }
                self.faults.stats.on_crash(now, active);
                self.crash_teardown(ev.device, q);
                self.fleet.sample(now, &self.devices);
                log::debug!("hft crash: instance {} fails at t={now:.2}", ev.device);
            }
            FaultKind::Recover => {
                if crate::cluster::recover_device(&mut self.devices, ev.device) {
                    let active = crate::cluster::active_count(&self.devices);
                    self.faults.stats.on_capacity_gain(now, active);
                    self.fleet.sample(now, &self.devices);
                    self.maybe_start(ev.device, q);
                }
            }
            FaultKind::SlowStart => {
                if self.devices[ev.device].state == DeviceState::Active {
                    self.devices[ev.device].slow_factor = self.fault_cfg.straggler_factor;
                    self.faults.stats.stragglers += 1;
                }
            }
            FaultKind::SlowEnd => {
                if self.devices[ev.device].state != DeviceState::Failed {
                    self.devices[ev.device].slow_factor = 1.0;
                }
            }
            FaultKind::LinkDegrade => {
                if ev.device < self.linkh.len() {
                    self.linkh[ev.device].slowdown = self.fault_cfg.link_degrade_factor;
                    self.faults.stats.link_degradations += 1;
                }
            }
            FaultKind::LinkPartition => {
                if ev.device < self.linkh.len() {
                    self.linkh[ev.device].partitioned = true;
                    self.faults.stats.link_degradations += 1;
                    self.abort_crossing_txs(ev.device);
                }
            }
            FaultKind::LinkRestore => {
                if ev.device < self.linkh.len() {
                    self.linkh[ev.device] = LinkHealth::default();
                }
            }
            // store nodes exist only in the BanaServe engine
            FaultKind::StoreCrash | FaultKind::StoreRecover => {}
        }
    }

    // --- transfer plane ----------------------------------------------------

    /// Live transfer transactions (tests: must drain back to 0).
    pub fn inflight_transfers(&self) -> usize {
        self.txs.len()
    }

    /// A partition on `dev` dooms every in-flight transfer crossing it.
    fn abort_crossing_txs(&mut self, dev: usize) {
        for (_, tx) in self.txs.iter_mut() {
            if tx.src == dev || tx.inst == dev {
                tx.aborted = true;
            }
        }
    }

    /// Issue (or re-issue) the spin-up transfer for tx `id` under the
    /// current path health, `delay` seconds from now (retry backoff).
    fn issue_spin_up(&mut self, id: u64, delay: f64, q: &mut EventQueue) {
        let tx = self.txs.get(id).expect("issuing a resolved tx");
        let health = cluster::path_health(self.linkh[tx.src], self.linkh[tx.inst]);
        let plan = xfer::plan(tx.t_nominal, health, self.fault_cfg.transfer_timeout_factor);
        if plan.doomed {
            q.push_after(delay + plan.deadline, FleetEvent::XferAbort { tx: id }.timer());
        } else {
            q.push_after(delay + plan.t_eff, FleetEvent::XferDone { tx: id }.timer());
        }
    }

    /// Spin-up transfer landed: unfreeze the instance and let it work.
    fn xfer_done(&mut self, id: u64, q: &mut EventQueue) {
        let aborted = match self.txs.get(id) {
            None => return, // already resolved (stale timer)
            Some(tx) => tx.aborted,
        };
        if aborted {
            return self.xfer_abort(id, q);
        }
        let tx = self.txs.remove(id).expect("live tx");
        let now = q.now();
        // transfer-plane mode: the true join time is only known now
        let dev = self.insts[tx.inst].device;
        if self.joined_at[dev].is_none() {
            self.joined_at[dev] = Some(now);
        }
        self.insts[tx.inst].frozen_until = now;
        self.maybe_start(tx.inst, q);
    }

    /// Spin-up transfer aborted (deadline or partition): retry within the
    /// budget; a final failure drains the half-born instance — its device
    /// never held weights or KV, so release is the exact rollback.
    fn xfer_abort(&mut self, id: u64, q: &mut EventQueue) {
        let now = q.now();
        let budget = self.fault_cfg.transfer_retries;
        let (retries, exhausted) = match self.txs.get_mut(id) {
            None => return, // already resolved (stale timer)
            Some(tx) => {
                self.faults.stats.transfer_timeouts += 1;
                if tx.retries < budget {
                    tx.retries += 1;
                    tx.aborted = false;
                    (tx.retries, false)
                } else {
                    (tx.retries, true)
                }
            }
        };
        if !exhausted {
            self.faults.stats.transfer_retries += 1;
            let delay = fault::backoff_delay(&self.fault_cfg, retries);
            self.issue_spin_up(id, delay, q);
            return;
        }
        let tx = self.txs.remove(id).expect("live tx");
        self.insts[tx.inst].frozen_until = now;
        if self.drainable(tx.inst) {
            self.begin_drain(tx.inst, q);
            self.finish_drains(now);
        } else {
            // last active instance: keep it (treat the late arrival of the
            // weights as done) rather than strand queued work forever
            let dev = self.insts[tx.inst].device;
            if self.joined_at[dev].is_none() {
                self.joined_at[dev] = Some(now);
            }
            self.maybe_start(tx.inst, q);
        }
    }

    /// Crash teardown of instance `i`: drop the whole static batch and its
    /// padded KV reservation, invalidate the in-flight step, re-route the
    /// waiting queue free of charge, retry-or-lose the batch residents.
    fn crash_teardown(&mut self, i: usize, q: &mut EventQueue) {
        let now = q.now();
        self.insts[i].step_token += 1; // in-flight StepDone becomes stale
        let dev = self.insts[i].device;
        if self.insts[i].step.take().is_some() {
            self.devices[dev].compute_util.set(now, 0.0);
        }
        if let Some(batch) = self.batches[i].take() {
            let reserve = batch.slot_kv * batch.seqs.len() as u64;
            self.devices[dev].free_kv(now, reserve);
            for &sid in &batch.seqs {
                let Some(seq) = self.seqs.get_mut(sid) else {
                    continue;
                };
                if seq.phase == SeqPhase::Finished {
                    // completed rows only waited for the batch: keep them
                    self.seqs.remove(sid);
                    continue;
                }
                self.crash_seq(sid, now, q);
            }
        }
        let waiting: Vec<u64> = self.insts[i].waiting.drain(..).collect();
        let (ql, ls) = (self.insts[i].queue_len(), self.insts[i].load_seqs());
        self.book.set_queue(i, ql, ls);
        for sid in waiting {
            // queued work lost nothing: re-route now, no retry charged
            self.admit_to_fleet(sid, q);
        }
        debug_assert_eq!(self.devices[dev].kv_bytes, 0, "crash must free all KV");
    }

    /// Retry path of one sequence that lost batch progress.
    fn crash_seq(&mut self, sid: u64, now: f64, q: &mut EventQueue) {
        let budget = self.fault_cfg.retry_budget;
        let seq = self.seqs.seq_mut(sid);
        // recompute recovery: all progress is gone (KV was the batch
        // reservation, freed wholesale by the caller)
        seq.ctx = 0;
        seq.generated = 0;
        seq.first_token = -1.0;
        seq.phase = SeqPhase::Waiting;
        seq.retries += 1;
        seq.crashed_at = now;
        let retries = seq.retries;
        if retries > budget {
            self.col.lost += 1;
            self.inflight -= 1;
            self.seqs.remove(sid);
        } else {
            self.faults.stats.retries += 1;
            let delay = fault::backoff_delay(&self.fault_cfg, retries);
            q.push_after(delay, FleetEvent::Requeue { seq: sid }.timer());
        }
    }

    /// Route a live sequence to an Active instance and enqueue it.
    fn admit_to_fleet(&mut self, sid: u64, q: &mut EventQueue) {
        let now = q.now();
        let target = self.route(now);
        self.seqs.seq_mut(sid).instance = self.insts[target].device;
        self.insts[target].waiting.push_back(sid);
        self.maybe_start(target, q);
    }

    /// Requeue timer: the sequence's crash-retry backoff expired.
    fn requeue(&mut self, sid: u64, q: &mut EventQueue) {
        match self.seqs.slots().get(sid as usize) {
            Some(Some(_)) => {}
            _ => return, // lost/finished in the meantime (defensive)
        }
        self.admit_to_fleet(sid, q);
    }

    // --- elastic fleet -----------------------------------------------------

    /// May instance `i` be drained? Never the last active instance.
    fn drainable(&self, i: usize) -> bool {
        self.devices[self.insts[i].device].is_active()
            && self
                .insts
                .iter()
                .filter(|x| self.devices[x.device].is_active())
                .count()
                > 1
    }

    /// Periodic autoscale evaluation (AUTOSCALE timer).
    fn autoscale_tick(&mut self, q: &mut EventQueue) {
        let now = q.now();
        let period = (now - self.as_last_eval).max(1e-9);
        self.finish_drains(now);
        let mut active = std::mem::take(&mut self.fleet_loads_buf);
        active.clear();
        for i in 0..self.insts.len() {
            if !self.devices[self.insts[i].device].is_active() {
                continue;
            }
            active.push(fleet::FleetLoad {
                idx: i,
                busy: ((self.insts[i].busy_wall - self.as_last_busy[i]) / period).min(1.0),
                queued: self.insts[i].queue_len(),
                resident: self.insts[i].load_seqs(),
                drainable: self.drainable(i),
                cost: self.devices[self.insts[i].device].spec.cost,
            });
        }
        if !active.is_empty() {
            let mean = active.iter().map(|l| l.busy).sum::<f64>() / active.len() as f64;
            self.fleet.util.push(now, mean);
        }
        let view = fleet::SloView {
            p99_ttft: self.slo.p99_ttft(now),
            p99_tpot: self.slo.p99_tpot(now),
        };
        let signal = self.forecaster.as_mut().map(|f| f.signal(now));
        let decision = self.autoscaler.decide_proactive(now, &active, 0, view, signal);
        self.fleet_loads_buf = active;
        match decision {
            fleet::ScaleDecision::Out => {
                let gap = self.autoscaler.slo_gap(view);
                self.scale_out(gap, q);
            }
            fleet::ScaleDecision::In { victim } => self.begin_drain(victim, q),
            fleet::ScaleDecision::Hold => {}
        }
        self.as_last_eval = now;
        for i in 0..self.insts.len() {
            self.as_last_busy[i] = self.insts[i].busy_wall;
        }
        // wake sweep: an unfrozen instance with queued work forms a batch
        for i in 0..self.insts.len() {
            self.maybe_start(i, q);
        }
        if self.inflight > 0 {
            q.push_after(self.autoscaler.cfg.window, FleetEvent::Autoscale.timer());
        } else {
            self.autoscale_ticking = false;
        }
    }

    /// Append a unified instance, frozen until its weight replica lands.
    fn scale_out(&mut self, slo_gap: f64, q: &mut EventQueue) {
        let now = q.now();
        let spec = fleet::pick_scale_out_spec(&self.catalog, slo_gap)
            .cloned()
            .unwrap_or_else(|| self.devices[0].spec.clone());
        let id = self.devices.len();
        let mut dev = Device::new(id, spec, Role::Unified);
        dev.weight_bytes = self.spec.weight_bytes();
        dev.touch_mem(now);
        self.devices.push(dev);
        let t_up = self.link.transfer_time(self.spec.weight_bytes());
        let mut inst = InstanceSim::new(id, 1.0);
        let plane = self.fault_cfg.transfer_plane();
        if plane {
            // transactional spin-up: frozen until the transfer resolves
            inst.frozen_until = f64::INFINITY;
        } else {
            inst.frozen_until = now + t_up;
        }
        self.insts.push(inst);
        self.linkh.push(LinkHealth::default());
        self.batches.push(None);
        // plane mode learns the real join time at spin-up resolution
        self.joined_at.push(if plane { None } else { Some(now + t_up) });
        if plane {
            let tx = self.txs.insert(xfer::SpinUp::new(id, t_up));
            self.issue_spin_up(tx, 0.0, q);
        }
        let bi = self.book.add_instance();
        self.book.entry_mut(bi).weight = self.devices[id].spec.weight;
        self.as_last_busy.push(0.0);
        self.scale_outs += 1;
        self.fleet.sample(now, &self.devices);
        log::debug!("hft scale-out: instance {id} joins at t={now:.2}");
    }

    /// Stop routing to `victim`, re-route its waiting queue now; the
    /// running batch finishes in place, then the device is released.
    fn begin_drain(&mut self, victim: usize, q: &mut EventQueue) {
        let now = q.now();
        crate::cluster::begin_drain(&mut self.devices, self.insts[victim].device);
        self.drains += 1;
        let mut stranded = std::mem::take(&mut self.stranded_buf);
        stranded.clear();
        stranded.extend(self.insts[victim].waiting.drain(..));
        let (ql, ls) = (self.insts[victim].queue_len(), self.insts[victim].load_seqs());
        self.book.set_queue(victim, ql, ls);
        for &sid in &stranded {
            let target = self.route(now);
            self.seqs.seq_mut(sid).instance = self.insts[target].device;
            self.insts[target].waiting.push_back(sid);
            self.maybe_start(target, q);
        }
        self.stranded_buf = stranded;
        self.fleet.sample(now, &self.devices);
        log::debug!("hft drain: instance {victim} begins draining at t={now:.2}");
    }

    /// Release drained devices whose residents are all gone (the shared
    /// `cluster::try_release` enforces the KV release-refusal invariant).
    fn finish_drains(&mut self, now: f64) {
        for i in 0..self.insts.len() {
            let d = self.insts[i].device;
            if self.devices[d].state != DeviceState::Draining {
                continue;
            }
            let clear = self.insts[i].waiting.is_empty()
                && self.batches[i].is_none()
                && self.insts[i].step.is_none();
            if crate::cluster::try_release(&mut self.devices, d, clear) {
                self.fleet.sample(now, &self.devices);
                log::debug!("hft release: instance {i} released at t={now:.2}");
            }
        }
    }

    pub fn device_utilization(&self, end: f64) -> Vec<(f64, f64)> {
        self.devices
            .iter()
            .map(|d| (d.compute_util.average(end), d.memory_util.average(end)))
            .collect()
    }
}

impl super::EngineHarness for HftEngine {
    fn build(cfg: &ExperimentConfig) -> Self {
        HftEngine::new(cfg)
    }

    fn fill_extras(&self, extras: &mut super::EngineExtras) {
        extras.scale_outs = self.scale_outs;
        extras.drains = self.drains;
        if self.post_scaleout_ttft.1 > 0 {
            extras.ttft_after_scaleout_s =
                self.post_scaleout_ttft.0 / self.post_scaleout_ttft.1 as f64;
        }
        if let Some(f) = &self.forecaster {
            extras.forecast_series = f.forecast_series().to_vec();
            extras.actual_rate_series = f.actual_series().to_vec();
        }
        self.faults.stats.fill_extras(extras);
    }

    fn fleet_series(&self) -> &fleet::FleetSeries {
        &self.fleet
    }

    fn devices(&self) -> &[Device] {
        &self.devices
    }

    fn device_utilization(&self, end: f64) -> Vec<(f64, f64)> {
        HftEngine::device_utilization(self, end)
    }
}

impl Engine for HftEngine {
    fn on_arrival(&mut self, req: Request, q: &mut EventQueue) {
        // every offered arrival counts toward the rate estimate, including
        // ones admission drops — demand is demand
        if let Some(f) = self.forecaster.as_mut() {
            f.observe(q.now());
        }
        if !fleet::admit_or_drop(self.spec, &self.devices[0].spec, &req, &mut self.col) {
            return;
        }
        // bootstrap the autoscale loop on (re-)arrival of work
        if self.autoscaler.enabled() && !self.autoscale_ticking {
            self.autoscale_ticking = true;
            let now = q.now();
            self.as_last_eval = now;
            for j in 0..self.insts.len() {
                self.as_last_busy[j] = self.insts[j].busy_wall;
            }
            if self.fleet.is_empty() {
                self.fleet.sample(now, &self.devices);
            }
            q.push_after(self.autoscaler.cfg.window, FleetEvent::Autoscale.timer());
        }
        let i = self.route(q.now());
        let mut seq = Seq::new(req);
        seq.instance = self.insts[i].device;
        let sid = self.seqs.insert(seq);
        self.inflight += 1;
        self.insts[i].waiting.push_back(sid);
        self.maybe_start(i, q);
        if self.faults.enabled() {
            self.service_faults(q);
        }
    }

    fn on_timer(&mut self, t: Timer, q: &mut EventQueue) {
        match FleetEvent::decode(t) {
            Some(FleetEvent::StepDone { worker, token }) => self.step_done(worker, token, q),
            Some(FleetEvent::Autoscale) => self.autoscale_tick(q),
            Some(FleetEvent::Fault) => {
                self.faults.armed = false;
                self.service_faults(q);
            }
            Some(FleetEvent::Requeue { seq }) => self.requeue(seq, q),
            Some(FleetEvent::XferDone { tx }) => self.xfer_done(tx, q),
            Some(FleetEvent::XferAbort { tx }) => self.xfer_abort(tx, q),
            _ => unreachable!("hft got unknown timer {t:?}"),
        }
    }

    fn collector(&mut self) -> &mut Collector {
        &mut self.col
    }

    fn inflight(&self) -> u64 {
        self.inflight
    }

    fn on_drain(&mut self, now: f64) {
        for d in self.devices.iter_mut() {
            d.compute_util.set(now, 0.0);
            d.touch_mem(now);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineKind, ExperimentConfig};
    use crate::sim;
    use crate::workload::{LengthProfile, WorkloadConfig};

    fn cfg(rps: f64, seed: u64) -> ExperimentConfig {
        let mut c = ExperimentConfig::default_for(EngineKind::HfStatic, "llama-13b", rps, seed);
        c.workload = WorkloadConfig::poisson(LengthProfile::AlpacaShort, rps, 20.0, seed);
        c.warmup = 0.0;
        c
    }

    #[test]
    fn completes_all_and_conserves() {
        let c = cfg(4.0, 1);
        let reqs = c.workload.generate();
        let n = reqs.len();
        let mut e = HftEngine::new(&c);
        let res = sim::run(&mut e, reqs, 1e6);
        assert_eq!(e.collector().completed() as usize, n);
        sim::check_conservation(&res, &mut e).unwrap();
    }

    #[test]
    fn kv_reservations_fully_released() {
        let c = cfg(6.0, 2);
        let reqs = c.workload.generate();
        let mut e = HftEngine::new(&c);
        sim::run(&mut e, reqs, 1e6);
        for d in &e.devices {
            assert_eq!(d.kv_bytes, 0);
        }
    }

    #[test]
    fn hft_slower_than_vllm_under_load() {
        // the Fig 1 comparison: same workload, HFT static batching must
        // deliver lower throughput than continuous batching.
        let c = cfg(10.0, 3);
        let reqs = c.workload.generate();
        let mut h = HftEngine::new(&c);
        let rh = sim::run(&mut h, reqs.clone(), 1e6);
        let hf = h.collector().report(rh.end_time);

        let mut cv = c.clone();
        cv.engine = EngineKind::Vllm;
        let mut v = super::super::vllm_sim::VllmEngine::new(&cv);
        let rv = sim::run(&mut v, reqs, 1e6);
        let vl = v.collector().report(rv.end_time);
        assert!(
            vl.throughput_tok_s > hf.throughput_tok_s,
            "vllm {:.1} must beat hft {:.1}",
            vl.throughput_tok_s,
            hf.throughput_tok_s
        );
    }

    #[test]
    fn elastic_fleet_scales_out_on_burst_and_conserves() {
        use crate::workload::ArrivalProcess;
        let mut c = cfg(4.0, 9);
        c.n_devices = 2;
        c.workload.duration = 50.0;
        c.workload.arrivals = ArrivalProcess::Bursty {
            rps: 4.0,
            burst_factor: 5.0,
            burst_secs: 8.0,
            period_secs: 24.0,
        };
        c.autoscale.enabled = true;
        c.autoscale.min_devices = 2;
        c.autoscale.max_devices = 5;
        let reqs = c.workload.generate();
        let n = reqs.len();
        let mut e = HftEngine::new(&c);
        let res = sim::run(&mut e, reqs, 1e6);
        assert_eq!(e.collector().completed() as usize, n);
        sim::check_conservation(&res, &mut e).unwrap();
        assert!(e.scale_outs > 0, "burst must trigger scale-out");
        for d in &e.devices {
            assert_eq!(d.kv_bytes, 0, "device {} leaked KV", d.id);
        }
    }

    #[test]
    fn later_arrivals_wait_for_batch_completion() {
        // one long batch, a later short request: with static batching its
        // TTFT must include the running batch's completion.
        let mut c = cfg(0.0, 4);
        c.n_devices = 1;
        let mk = |id, at, out| Request {
            id,
            arrival: at,
            prompt_len: 50,
            output_len: out,
            cache_tokens: vec![id as u32].into(),
        };
        let reqs = vec![mk(0, 0.0, 400), mk(1, 0.1, 4)];
        let mut e = HftEngine::new(&c);
        sim::run(&mut e, reqs, 1e6);
        let recs = &e.col.records;
        let r1 = recs.iter().find(|r| r.id == 1).unwrap();
        let r0 = recs.iter().find(|r| r.id == 0).unwrap();
        assert!(
            r1.first_token >= r0.completion,
            "request 1 must wait for the whole batch 0 run"
        );
    }
}
