//! Machinery shared by all simulated engines: the sequence table, the
//! per-instance continuous-batching state machine, step formation, KV
//! accounting, and timer-tag conventions.
//!
//! An [`InstanceSim`] is one *logical* worker bound to a device. Monolithic
//! engines bind one `Unified` instance per device; PD-disaggregated engines
//! bind a `Prefill` or `Decode` instance; BanaServe may bind *both* to one
//! device with fractional capacity shares (the effect of layer-level
//! migration — a device dedicating k/L of its layers to the other phase).

use crate::cluster::Device;
use crate::metrics::RequestRecord;
use crate::perfmodel::{self, Efficiency, PrefillItem, StepTime};
use crate::model::ModelSpec;
use crate::workload::Request;
use std::collections::VecDeque;

/// Timer tags (Timer.tag values) used by all engines. Engines no longer
/// match on these directly — [`super::fleet::FleetEvent`] is the typed
/// encode/decode layer over them; the raw constants remain the stable wire
/// format inside [`crate::sim::Timer`].
pub mod tags {
    /// A compute step finished on instance `a`.
    pub const STEP_DONE: u64 = 1;
    /// KV of sequence `b` arrived at decode instance `a`.
    pub const KV_ARRIVE: u64 = 2;
    /// Orchestrator control cycle (BanaServe).
    pub const CONTROL: u64 = 3;
    /// Module migration to instance `a` completed.
    pub const MIG_DONE: u64 = 4;
    /// Elastic-fleet autoscale evaluation tick.
    pub const AUTOSCALE: u64 = 5;
    /// Next due fault-plan entry (crash / recovery / straggler edge).
    pub const FAULT: u64 = 6;
    /// Crash-retry backoff expired for sequence `a`: re-admit it.
    pub const REQUEUE: u64 = 7;
    /// Transfer transaction `a` completed (transfer plane).
    pub const XFER_DONE: u64 = 8;
    /// Transfer transaction `a` hit its deadline: abort + rollback.
    pub const XFER_ABORT: u64 = 9;
}

/// KV page size in tokens used by all simulated paged engines.
pub const BLOCK_TOKENS: u64 = 16;

/// Round `tokens` up to whole KV blocks (paged allocation granularity).
pub fn kv_block_tokens(tokens: u64) -> u64 {
    tokens.div_ceil(BLOCK_TOKENS) * BLOCK_TOKENS
}

/// KV bytes a sequence of context `ctx` holds, block-rounded.
pub fn kv_bytes(spec: &ModelSpec, ctx: u64) -> u64 {
    kv_block_tokens(ctx) * spec.kv_bytes_per_token()
}

/// Admission control: can a request (prompt + full output) EVER fit in one
/// device's post-weight HBM? Serving systems enforce this as max-model-len;
/// without it an oversized head-of-line request deadlocks the queue.
pub fn request_fits(spec: &ModelSpec, gpu: &crate::cluster::GpuSpec, req: &Request) -> bool {
    let usable = gpu.hbm_bytes.saturating_sub(spec.weight_bytes());
    kv_bytes(spec, req.prompt_len + req.output_len + 1) <= usable
}

/// Lifecycle of a request inside an engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqPhase {
    /// Queued at a prefill (or unified) instance.
    Waiting,
    /// Inside a running prefill step.
    Prefilling,
    /// KV in flight to a decode instance (PD engines).
    Transferring,
    /// In a decode instance's running set.
    Decoding,
    Finished,
}

/// A request in service.
#[derive(Debug, Clone)]
pub struct Seq {
    pub req: Request,
    pub phase: SeqPhase,
    /// Tokens of prompt served from prefix cache.
    pub cached: u64,
    /// Current context length (prompt + generated so far).
    pub ctx: u64,
    pub generated: u64,
    /// Instance currently responsible for the seq.
    pub instance: usize,
    pub prefill_start: f64,
    pub first_token: f64,
    /// KV bytes charged to `instance`'s device.
    pub kv_on_device: u64,
    /// Times this sequence was preempted (recompute).
    pub preemptions: u32,
    /// Residual Global-KV-Store fetch stall to fold into this seq's
    /// prefill step (0 when the layer-wise pipeline fully hides it).
    pub store_stall: f64,
    /// PD handoff: KV staging (store write / direct push) has completed and
    /// the sequence is eligible for decode admission.
    pub staged: bool,
    /// Times this sequence was re-admitted after a device crash.
    pub retries: u32,
    /// Time of the most recent crash that evicted this sequence, or < 0 when
    /// it is not currently in a recovery path (used for recovery latency).
    pub crashed_at: f64,
}

impl Seq {
    pub fn new(req: Request) -> Self {
        Seq {
            req,
            phase: SeqPhase::Waiting,
            cached: 0,
            ctx: 0,
            generated: 0,
            instance: usize::MAX,
            prefill_start: -1.0,
            first_token: -1.0,
            kv_on_device: 0,
            preemptions: 0,
            store_stall: 0.0,
            staged: false,
            retries: 0,
            crashed_at: -1.0,
        }
    }

    pub fn is_done(&self) -> bool {
        self.generated >= self.req.output_len
    }

    pub fn record(&self, completion: f64) -> RequestRecord {
        RequestRecord {
            id: self.req.id,
            arrival: self.req.arrival,
            prefill_start: if self.prefill_start >= 0.0 {
                self.prefill_start
            } else {
                self.req.arrival
            },
            first_token: self.first_token,
            completion,
            prompt_len: self.req.prompt_len,
            output_len: self.req.output_len,
            cached_tokens: self.cached,
        }
    }
}

/// What a running step is doing.
#[derive(Debug, Clone, PartialEq)]
pub enum StepKind {
    Prefill,
    Decode,
    /// HFT static batching: lock-step decode of a fixed batch (padded).
    StaticDecode,
}

/// An in-flight compute step on an instance.
#[derive(Debug, Clone)]
pub struct StepInfo {
    pub kind: StepKind,
    pub seqs: Vec<u64>,
    pub st: StepTime,
    /// Extra latency folded into this step (KV-store stall, merge exchange).
    pub overhead: f64,
}

/// One logical worker bound to a device.
#[derive(Debug)]
pub struct InstanceSim {
    /// Index into the engine's device table.
    pub device: usize,
    /// Capacity share of the device this logical instance owns (0..1].
    pub share: f64,
    /// Waiting prefill queue (seq ids).
    pub waiting: VecDeque<u64>,
    /// Running decode set (seq ids).
    pub running: Vec<u64>,
    /// Current step, if the instance is busy.
    pub step: Option<StepInfo>,
    /// Unavailable until this time (module migration in progress).
    pub frozen_until: f64,
    /// Per-decode-step overhead (attention-level migration exchange, Eq 10
    /// round trip) charged while remote KV heads are active.
    pub decode_overhead: f64,
    /// Cumulative busy seconds weighted by compute fraction.
    pub busy_compute: f64,
    /// Cumulative busy wall seconds.
    pub busy_wall: f64,
    /// Step token carried by StepDone timers; a crash teardown bumps it so
    /// the torn-down step's in-flight StepDone is recognized as stale.
    pub step_token: u64,
}

impl InstanceSim {
    pub fn new(device: usize, share: f64) -> Self {
        InstanceSim {
            device,
            share,
            waiting: VecDeque::new(),
            running: Vec::new(),
            step: None,
            frozen_until: 0.0,
            decode_overhead: 0.0,
            busy_compute: 0.0,
            busy_wall: 0.0,
            step_token: 0,
        }
    }

    pub fn is_busy(&self) -> bool {
        self.step.is_some()
    }

    /// Queue depth metric used by the routers (Alg 2's q_len).
    pub fn queue_len(&self) -> usize {
        self.waiting.len()
    }

    /// Total load proxy: waiting + running.
    pub fn load_seqs(&self) -> usize {
        self.waiting.len() + self.running.len()
    }
}

/// Admission/step limits.
#[derive(Debug, Clone, Copy)]
pub struct BatchLimits {
    pub max_batch_tokens: u64,
    pub max_batch_seqs: u64,
}

/// Form a prefill step from an instance's waiting queue.
///
/// Greedily admits sequences while the *computed* (non-cached) token budget
/// holds and the device can fit their full prompt KV. Returns the selected
/// seq ids and their prefill items; does NOT mutate KV accounting (caller
/// charges the device when the step starts).
pub fn plan_prefill(
    inst: &mut InstanceSim,
    seqs: &[Option<Seq>],
    device: &Device,
    spec: &ModelSpec,
    limits: &BatchLimits,
) -> (Vec<u64>, Vec<PrefillItem>) {
    let mut chosen = Vec::new();
    let mut items = Vec::new();
    let mut tokens: u64 = 0;
    let mut mem_budget = device.mem_free();
    while let Some(&sid) = inst.waiting.front() {
        let seq = seqs[sid as usize].as_ref().expect("live seq");
        let compute = seq.req.prompt_len - seq.cached.min(seq.req.prompt_len);
        // +1 in kv for the first generated token's slot
        let need_kv = kv_bytes(spec, seq.req.prompt_len + 1);
        if !chosen.is_empty()
            && (tokens + compute > limits.max_batch_tokens
                || chosen.len() as u64 >= limits.max_batch_seqs)
        {
            break;
        }
        if need_kv > mem_budget {
            // head-of-line blocks on memory: stop (FCFS, no reordering)
            break;
        }
        inst.waiting.pop_front();
        tokens += compute;
        mem_budget -= need_kv;
        items.push(PrefillItem {
            prompt: seq.req.prompt_len,
            cached: seq.cached,
        });
        chosen.push(sid);
    }
    (chosen, items)
}

/// Compute a decode step over the instance's running set (up to the batch
/// cap), returning (ids, StepTime). The caller handles KV growth.
pub fn plan_decode(
    inst: &InstanceSim,
    seqs: &[Option<Seq>],
    spec: &ModelSpec,
    gpu: &crate::cluster::GpuSpec,
    eff: &Efficiency,
    limits: &BatchLimits,
) -> (Vec<u64>, StepTime) {
    let ids: Vec<u64> = inst
        .running
        .iter()
        .copied()
        .take(limits.max_batch_seqs as usize)
        .collect();
    let total_ctx: u64 = ids
        .iter()
        .map(|&sid| seqs[sid as usize].as_ref().unwrap().ctx)
        .sum();
    let st = perfmodel::decode_step(spec, gpu, eff, ids.len() as u64, total_ctx, inst.share);
    (ids, st)
}

/// Record step utilization on the device trackers when a step starts/ends.
pub fn mark_step_start(dev: &mut Device, inst: &mut InstanceSim, now: f64, st: &StepTime) {
    dev.compute_util.set(now, st.compute_frac() * inst.share.min(1.0));
}

pub fn mark_step_end(
    dev: &mut Device,
    inst: &mut InstanceSim,
    now: f64,
    duration: f64,
    st: &StepTime,
) {
    inst.busy_wall += duration;
    inst.busy_compute += duration * st.compute_frac();
    dev.compute_util.set(now, 0.0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{A100_80G, Role};
    use crate::model::LLAMA_13B;

    fn mkreq(id: u64, prompt: u64, out: u64) -> Request {
        Request {
            id,
            arrival: 0.0,
            prompt_len: prompt,
            output_len: out,
            cache_tokens: (0..prompt.min(64) as u32).collect::<Vec<u32>>().into(),
        }
    }

    fn seq_table(reqs: Vec<Request>) -> Vec<Option<Seq>> {
        reqs.into_iter().map(|r| Some(Seq::new(r))).collect()
    }

    #[test]
    fn kv_block_rounding() {
        assert_eq!(kv_block_tokens(0), 0);
        assert_eq!(kv_block_tokens(1), 16);
        assert_eq!(kv_block_tokens(16), 16);
        assert_eq!(kv_block_tokens(17), 32);
    }

    #[test]
    fn plan_prefill_respects_token_budget() {
        let mut inst = InstanceSim::new(0, 1.0);
        let seqs = seq_table((0..10).map(|i| mkreq(i, 1000, 10)).collect());
        for i in 0..10 {
            inst.waiting.push_back(i);
        }
        let mut dev = Device::new(0, A100_80G, Role::Prefill);
        dev.weight_bytes = LLAMA_13B.weight_bytes();
        let limits = BatchLimits {
            max_batch_tokens: 2500,
            max_batch_seqs: 64,
        };
        let (ids, items) = plan_prefill(&mut inst, &seqs, &dev, &LLAMA_13B, &limits);
        // 1000 + 1000 fits, third would exceed 2500 -> 2 or 3 (first always admitted)
        assert_eq!(ids.len(), 2);
        assert_eq!(items.len(), 2);
        assert_eq!(inst.waiting.len(), 8);
    }

    #[test]
    fn plan_prefill_first_seq_always_admitted_even_if_over_budget() {
        // over the TOKEN budget (memory is a hard constraint and stays one)
        let mut inst = InstanceSim::new(0, 1.0);
        let seqs = seq_table(vec![mkreq(0, 20_000, 1)]);
        inst.waiting.push_back(0);
        let mut dev = Device::new(0, A100_80G, Role::Prefill);
        dev.weight_bytes = LLAMA_13B.weight_bytes();
        let limits = BatchLimits {
            max_batch_tokens: 1024,
            max_batch_seqs: 8,
        };
        let (ids, _) = plan_prefill(&mut inst, &seqs, &dev, &LLAMA_13B, &limits);
        assert_eq!(ids.len(), 1, "oversized head must still run alone");
    }

    #[test]
    fn plan_prefill_blocks_on_memory() {
        let mut inst = InstanceSim::new(0, 1.0);
        let seqs = seq_table(vec![mkreq(0, 8000, 1), mkreq(1, 8000, 1)]);
        inst.waiting.push_back(0);
        inst.waiting.push_back(1);
        let mut dev = Device::new(0, A100_80G, Role::Prefill);
        // leave room for ~1 seq of KV only: 8000 tok * 400KB/tok ≈ 3.2GB
        dev.weight_bytes = A100_80G.hbm_bytes - 2 * kv_bytes(&LLAMA_13B, 8001) + 1000;
        let limits = BatchLimits {
            max_batch_tokens: 1 << 40,
            max_batch_seqs: 64,
        };
        let (ids, _) = plan_prefill(&mut inst, &seqs, &dev, &LLAMA_13B, &limits);
        assert_eq!(ids.len(), 1, "second must block on KV memory");
        assert_eq!(inst.waiting.len(), 1);
    }

    #[test]
    fn plan_prefill_cached_tokens_reduce_budget_use() {
        let mut inst = InstanceSim::new(0, 1.0);
        let mut seqs = seq_table((0..4).map(|i| mkreq(i, 1000, 1)).collect());
        for s in seqs.iter_mut().flatten() {
            s.cached = 900; // 90% prefix hit
        }
        for i in 0..4 {
            inst.waiting.push_back(i);
        }
        let mut dev = Device::new(0, A100_80G, Role::Prefill);
        dev.weight_bytes = LLAMA_13B.weight_bytes();
        let limits = BatchLimits {
            max_batch_tokens: 350,
            max_batch_seqs: 64,
        };
        let (ids, items) = plan_prefill(&mut inst, &seqs, &dev, &LLAMA_13B, &limits);
        assert_eq!(ids.len(), 3, "only 100 computed tokens each");
        assert!(items.iter().all(|i| i.cached == 900));
    }

    #[test]
    fn plan_decode_sums_context() {
        let mut inst = InstanceSim::new(0, 1.0);
        let mut seqs = seq_table(vec![mkreq(0, 10, 5), mkreq(1, 20, 5)]);
        seqs[0].as_mut().unwrap().ctx = 11;
        seqs[1].as_mut().unwrap().ctx = 22;
        inst.running = vec![0, 1];
        let limits = BatchLimits {
            max_batch_tokens: 8192,
            max_batch_seqs: 64,
        };
        let (ids, st) = plan_decode(
            &inst,
            &seqs,
            &LLAMA_13B,
            &A100_80G,
            &Efficiency::default(),
            &limits,
        );
        assert_eq!(ids, vec![0, 1]);
        assert!(st.time > 0.0);
    }

    #[test]
    fn seq_record_roundtrip() {
        let mut s = Seq::new(mkreq(7, 10, 3));
        s.prefill_start = 1.0;
        s.first_token = 2.0;
        s.generated = 3;
        let rec = s.record(5.0);
        assert_eq!(rec.id, 7);
        assert!((rec.ttft() - 2.0).abs() < 1e-12);
        assert!((rec.e2e() - 5.0).abs() < 1e-12);
    }
}
