//! Workload generation (paper §5.1.2-§5.1.3): Alpaca-like short-context and
//! LongBench-like long-context request streams, Poisson or bursty arrivals,
//! controllable prefix sharing, and trace record/replay.
//!
//! The evaluation consumes only the request *marginals* — input length,
//! output length, arrival time, and prefix shareability — so the generators
//! reproduce those (Fig 7a: 4-50 token inputs; Fig 7b: ~2k-85k; output
//! capped at 512 in all experiments).

use crate::util::json::{self, Value};
use crate::util::prng::{Rng, Zipf};
use std::sync::Arc;

/// Cacheable-prefix length cap: only the first `CACHE_TOKEN_CAP` tokens of a
/// prompt participate in prefix matching (bounds radix-tree memory for 85k-
/// token LongBench prompts without changing behaviour — sharing beyond this
/// depth is negligible in all workloads).
pub const CACHE_TOKEN_CAP: usize = 4096;

/// One inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time (seconds from experiment start).
    pub arrival: f64,
    /// Full prompt length in tokens.
    pub prompt_len: u64,
    /// Number of output tokens this request will generate.
    pub output_len: u64,
    /// The cacheable token prefix (capped) used for prefix matching.
    /// Shared (`Arc<[u32]>`) so engines clone a handle, not the tokens:
    /// per-step store/cache writes are pointer bumps, not memcpys.
    pub cache_tokens: Arc<[u32]>,
}

impl Request {
    /// Tokens of the prompt that are *sharable* (present in cache_tokens).
    pub fn cacheable_len(&self) -> u64 {
        self.cache_tokens.len() as u64
    }
}

/// Which benchmark's length distribution to draw from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LengthProfile {
    /// Alpaca: short instruction-following prompts, 4-50 tokens (Fig 7a).
    AlpacaShort,
    /// LongBench: long-context, ~2k-85k tokens (Fig 7b).
    LongBench,
}

impl LengthProfile {
    /// Sample an input length.
    pub fn sample_input(&self, rng: &mut Rng) -> u64 {
        match self {
            LengthProfile::AlpacaShort => {
                // log-normal centered near ~18 tokens, clipped to [4, 50]
                let x = rng.lognormal(2.9, 0.55);
                (x.round() as u64).clamp(4, 50)
            }
            LengthProfile::LongBench => {
                // log-normal spanning 2k..85k, median ~8k
                let x = rng.lognormal(9.0, 0.85);
                (x.round() as u64).clamp(2_000, 85_000)
            }
        }
    }

    /// Sample an output length (capped at 512 per the paper's methodology).
    pub fn sample_output(&self, rng: &mut Rng) -> u64 {
        let x = match self {
            LengthProfile::AlpacaShort => rng.lognormal(5.0, 0.6),
            LengthProfile::LongBench => rng.lognormal(4.6, 0.6),
        };
        (x.round() as u64).clamp(1, 512)
    }
}

/// Arrival process.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Stationary Poisson at `rps` requests/second.
    Poisson { rps: f64 },
    /// On/off modulated Poisson: `burst_factor`×rps during bursts of
    /// `burst_secs`, base rate otherwise, cycling every `period_secs`.
    Bursty {
        rps: f64,
        burst_factor: f64,
        burst_secs: f64,
        period_secs: f64,
    },
    /// Day/night rate envelope with periodic bursts riding on top (the
    /// ROADMAP "million-user" diurnal trace): rate follows a raised-cosine
    /// between `rps_peak` and `rps_peak / day_night_ratio` over a
    /// `day_secs`-long day, and every `burst_period` seconds a
    /// `burst_secs`-long window multiplies the envelope by `burst_factor`
    /// (the "everyone opens the app at 9am" spike).
    Diurnal {
        rps_peak: f64,
        day_night_ratio: f64,
        day_secs: f64,
        burst_factor: f64,
        burst_secs: f64,
        burst_period: f64,
    },
}

impl ArrivalProcess {
    /// Diurnal process with the default burst shape: 1.5× spikes lasting
    /// 1/20 of a day, every 1/4 day.
    pub fn diurnal(rps_peak: f64, day_night_ratio: f64, day_secs: f64) -> Self {
        ArrivalProcess::Diurnal {
            rps_peak,
            day_night_ratio: day_night_ratio.max(1.0),
            day_secs: day_secs.max(1e-9),
            burst_factor: 1.5,
            burst_secs: day_secs.max(1e-9) / 20.0,
            burst_period: day_secs.max(1e-9) / 4.0,
        }
    }

    /// The nominal peak rate of the process (the `rps` knob an operator
    /// would size capacity against). Used by config layering: `--rps` sets
    /// the peak, `--diurnal-ratio` reshapes around it.
    pub fn peak(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rps } => rps,
            ArrivalProcess::Bursty { rps, .. } => rps,
            ArrivalProcess::Diurnal { rps_peak, .. } => rps_peak,
        }
    }
    /// Instantaneous rate at time t.
    pub fn rate_at(&self, t: f64) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rps } => rps,
            ArrivalProcess::Bursty {
                rps,
                burst_factor,
                burst_secs,
                period_secs,
            } => {
                let phase = t % period_secs;
                if phase < burst_secs {
                    rps * burst_factor
                } else {
                    rps
                }
            }
            ArrivalProcess::Diurnal {
                rps_peak,
                day_night_ratio,
                day_secs,
                burst_factor,
                burst_secs,
                burst_period,
            } => {
                let trough = rps_peak / day_night_ratio;
                // raised cosine: rate(0) = trough (midnight), rate(day/2) = peak
                let envelope = trough
                    + (rps_peak - trough)
                        * 0.5
                        * (1.0 - (2.0 * std::f64::consts::PI * t / day_secs).cos());
                let phase = t % burst_period;
                if phase < burst_secs {
                    envelope * burst_factor
                } else {
                    envelope
                }
            }
        }
    }

    /// Generate arrival times in [0, duration) by thinning.
    pub fn arrivals(&self, duration: f64, rng: &mut Rng) -> Vec<f64> {
        let max_rate = match *self {
            ArrivalProcess::Poisson { rps } => rps,
            ArrivalProcess::Bursty {
                rps, burst_factor, ..
            } => rps * burst_factor,
            ArrivalProcess::Diurnal {
                rps_peak,
                burst_factor,
                ..
            } => rps_peak * burst_factor,
        };
        let mut out = Vec::new();
        let mut t = 0.0;
        loop {
            t += rng.exponential(max_rate);
            if t >= duration {
                break;
            }
            // thin to the instantaneous rate
            if rng.f64() < self.rate_at(t) / max_rate {
                out.push(t);
            }
        }
        out
    }
}

/// Prefix-sharing model: a Zipf-popular pool of prompt templates whose
/// leading tokens are shared between requests (system prompts, few-shot
/// preambles). This is the mechanism that makes cache-aware routing skew
/// load (Fig 2a) and that the Global KV Cache Store neutralizes.
#[derive(Debug, Clone)]
pub struct PrefixConfig {
    /// Probability a request uses a shared template at all.
    pub share_prob: f64,
    /// Number of distinct templates.
    pub n_templates: usize,
    /// Zipf skew of template popularity.
    pub zipf_s: f64,
    /// Shared fraction of the prompt drawn uniformly from this range.
    pub shared_frac: (f64, f64),
}

impl Default for PrefixConfig {
    fn default() -> Self {
        PrefixConfig {
            share_prob: 0.5,
            n_templates: 32,
            zipf_s: 1.1,
            shared_frac: (0.3, 0.9),
        }
    }
}

impl PrefixConfig {
    pub fn none() -> Self {
        PrefixConfig {
            share_prob: 0.0,
            n_templates: 1,
            zipf_s: 1.0,
            shared_frac: (0.0, 0.0),
        }
    }
}

/// Multi-tenant mixing: each request belongs to a Zipf-popular tenant, and
/// tenants have *disjoint* template pools (tenant t's template j is globally
/// `t * n_templates + j`). One tenant (the default) degenerates to the
/// single-pool behaviour with zero extra PRNG draws, so every existing
/// fixed-seed trace stays byte-identical.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TenantConfig {
    /// Number of tenants (1 = single-tenant, the legacy behaviour).
    pub n_tenants: usize,
    /// Zipf skew of tenant popularity.
    pub zipf_s: f64,
}

impl Default for TenantConfig {
    fn default() -> Self {
        TenantConfig {
            n_tenants: 1,
            zipf_s: 1.1,
        }
    }
}

/// Full workload description.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub profile: LengthProfile,
    pub arrivals: ArrivalProcess,
    pub duration: f64,
    pub seed: u64,
    pub prefix: PrefixConfig,
    pub tenants: TenantConfig,
}

impl WorkloadConfig {
    pub fn poisson(profile: LengthProfile, rps: f64, duration: f64, seed: u64) -> Self {
        WorkloadConfig {
            profile,
            arrivals: ArrivalProcess::Poisson { rps },
            duration,
            seed,
            prefix: PrefixConfig::default(),
            tenants: TenantConfig::default(),
        }
    }

    /// Generate the request stream.
    pub fn generate(&self) -> Vec<Request> {
        let root = Rng::new(self.seed);
        let mut r_arr = root.substream("arrivals");
        let mut r_len = root.substream("lengths");
        let mut r_pfx = root.substream("prefixes");
        // tenant draws live on their own substream so enabling multi-tenancy
        // never shifts the arrival/length/prefix streams
        let mut r_ten = root.substream("tenants");
        let zipf = Zipf::new(self.prefix.n_templates.max(1), self.prefix.zipf_s);
        let tenant_zipf = Zipf::new(self.tenants.n_tenants.max(1), self.tenants.zipf_s);

        let times = self.arrivals.arrivals(self.duration, &mut r_arr);
        let mut out = Vec::with_capacity(times.len());
        let mut unique_counter: u32 = 1 << 24; // unique-token namespace

        for (i, t) in times.into_iter().enumerate() {
            let prompt_len = self.profile.sample_input(&mut r_len);
            let output_len = self.profile.sample_output(&mut r_len);
            let cacheable = prompt_len.min(CACHE_TOKEN_CAP as u64) as usize;

            let mut cache_tokens = Vec::with_capacity(cacheable);
            if self.prefix.share_prob > 0.0 && r_pfx.chance(self.prefix.share_prob) {
                let local = zipf.sample(&mut r_pfx) as u32;
                // tenant 0 with zero draws when multi-tenancy is off: the
                // template id (and thus every token) is unchanged
                let tenant = if self.tenants.n_tenants > 1 {
                    tenant_zipf.sample(&mut r_ten) as u32
                } else {
                    0
                };
                let template = tenant * self.prefix.n_templates as u32 + local;
                let (lo, hi) = self.prefix.shared_frac;
                let frac = lo + r_pfx.f64() * (hi - lo);
                let shared = ((cacheable as f64 * frac) as usize).min(cacheable);
                // template tokens are a deterministic function of (template, pos)
                for p in 0..shared {
                    cache_tokens.push(template.wrapping_mul(31) ^ (p as u32) | 0x8000_0000);
                }
                for _ in shared..cacheable {
                    cache_tokens.push(unique_counter);
                    unique_counter += 1;
                }
            } else {
                for _ in 0..cacheable {
                    cache_tokens.push(unique_counter);
                    unique_counter += 1;
                }
            }

            out.push(Request {
                id: i as u64,
                arrival: t,
                prompt_len,
                output_len,
                cache_tokens: cache_tokens.into(),
            });
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Trace record / replay
// ---------------------------------------------------------------------------

/// Serialize a request stream to JSON (compact: cache tokens included).
pub fn trace_to_json(reqs: &[Request]) -> String {
    let arr: Vec<Value> = reqs
        .iter()
        .map(|r| {
            json::obj(vec![
                ("id", json::num(r.id as f64)),
                ("arrival", json::num(r.arrival)),
                ("prompt_len", json::num(r.prompt_len as f64)),
                ("output_len", json::num(r.output_len as f64)),
                (
                    "cache_tokens",
                    json::arr(
                        r.cache_tokens.iter().map(|&t| json::num(t as f64)).collect(),
                    ),
                ),
            ])
        })
        .collect();
    json::write(&json::arr(arr))
}

/// Parse a trace back.
pub fn trace_from_json(text: &str) -> Result<Vec<Request>, String> {
    let v = json::parse(text).map_err(|e| e.to_string())?;
    let arr = v.as_arr().ok_or("trace must be an array")?;
    let mut out = Vec::with_capacity(arr.len());
    for item in arr {
        let get = |k: &str| -> Result<f64, String> {
            item.get(k)
                .and_then(|x| x.as_f64())
                .ok_or(format!("missing field {k}"))
        };
        let toks = item
            .get("cache_tokens")
            .and_then(|x| x.as_arr())
            .ok_or("missing cache_tokens")?
            .iter()
            .map(|t| t.as_f64().map(|f| f as u32).ok_or("bad token"))
            .collect::<Result<Vec<u32>, _>>()?;
        out.push(Request {
            id: get("id")? as u64,
            arrival: get("arrival")?,
            prompt_len: get("prompt_len")? as u64,
            output_len: get("output_len")? as u64,
            cache_tokens: toks.into(),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(rps: f64, seed: u64) -> WorkloadConfig {
        WorkloadConfig::poisson(LengthProfile::AlpacaShort, rps, 60.0, seed)
    }

    #[test]
    fn alpaca_lengths_in_fig7a_range() {
        let mut rng = Rng::new(1);
        for _ in 0..2000 {
            let l = LengthProfile::AlpacaShort.sample_input(&mut rng);
            assert!((4..=50).contains(&l), "alpaca len {l}");
        }
    }

    #[test]
    fn longbench_lengths_in_fig7b_range() {
        let mut rng = Rng::new(2);
        let mut max = 0;
        let mut min = u64::MAX;
        for _ in 0..5000 {
            let l = LengthProfile::LongBench.sample_input(&mut rng);
            assert!((2_000..=85_000).contains(&l));
            max = max.max(l);
            min = min.min(l);
        }
        assert!(min < 3_000, "distribution must reach short end: {min}");
        assert!(max > 40_000, "distribution must reach long tail: {max}");
    }

    #[test]
    fn outputs_capped_at_512() {
        let mut rng = Rng::new(3);
        for profile in [LengthProfile::AlpacaShort, LengthProfile::LongBench] {
            for _ in 0..2000 {
                let l = profile.sample_output(&mut rng);
                assert!((1..=512).contains(&l));
            }
        }
    }

    #[test]
    fn poisson_rate_approximately_met() {
        let w = cfg(10.0, 4);
        let reqs = w.generate();
        let rate = reqs.len() as f64 / 60.0;
        assert!((8.0..12.0).contains(&rate), "rate = {rate}");
        // arrivals sorted
        for w in reqs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }

    #[test]
    fn bursty_rate_is_higher_in_burst_windows() {
        let proc = ArrivalProcess::Bursty {
            rps: 5.0,
            burst_factor: 5.0,
            burst_secs: 10.0,
            period_secs: 60.0,
        };
        let mut rng = Rng::new(5);
        let times = proc.arrivals(600.0, &mut rng);
        let in_burst = times
            .iter()
            .filter(|t| (*t % 60.0) < 10.0)
            .count() as f64;
        let out_burst = times.len() as f64 - in_burst;
        // burst windows are 1/6 of time but 5x rate -> expect ~equal counts
        let ratio = in_burst / out_burst.max(1.0);
        assert!((0.6..1.7).contains(&ratio), "ratio = {ratio}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = cfg(5.0, 42).generate();
        let b = cfg(5.0, 42).generate();
        assert_eq!(a, b);
        let c = cfg(5.0, 43).generate();
        assert_ne!(a, c);
    }

    #[test]
    fn prefix_sharing_produces_common_prefixes() {
        let mut w = cfg(20.0, 7);
        w.prefix = PrefixConfig {
            share_prob: 1.0,
            n_templates: 2,
            zipf_s: 1.0,
            shared_frac: (0.5, 0.5),
        };
        let reqs = w.generate();
        // with 2 templates and forced sharing, many pairs share a first token
        let mut firsts: Vec<u32> = reqs
            .iter()
            .filter(|r| !r.cache_tokens.is_empty())
            .map(|r| r.cache_tokens[0])
            .collect();
        firsts.sort_unstable();
        firsts.dedup();
        assert!(firsts.len() <= 2, "template firsts = {firsts:?}");
    }

    #[test]
    fn no_sharing_when_disabled() {
        let mut w = cfg(20.0, 8);
        w.prefix = PrefixConfig::none();
        let reqs = w.generate();
        let mut firsts: Vec<u32> = reqs.iter().map(|r| r.cache_tokens[0]).collect();
        let total = firsts.len();
        firsts.sort_unstable();
        firsts.dedup();
        assert_eq!(firsts.len(), total, "all prompts must be unique");
    }

    #[test]
    fn cache_tokens_capped_for_long_prompts() {
        let w = WorkloadConfig::poisson(LengthProfile::LongBench, 2.0, 30.0, 9);
        let reqs = w.generate();
        for r in &reqs {
            assert!(r.cache_tokens.len() <= CACHE_TOKEN_CAP);
            assert!(r.cacheable_len() <= r.prompt_len);
        }
    }

    #[test]
    fn trace_roundtrip() {
        let reqs = cfg(3.0, 10).generate();
        let text = trace_to_json(&reqs);
        let back = trace_from_json(&text).unwrap();
        assert_eq!(reqs, back);
    }

    #[test]
    fn trace_roundtrip_preserves_prefix_groups() {
        // shared-template workload: the prefix-group structure (which
        // requests share which leading tokens) must survive record/replay
        let mut w = cfg(10.0, 21);
        w.prefix = PrefixConfig {
            share_prob: 1.0,
            n_templates: 3,
            zipf_s: 1.2,
            shared_frac: (0.5, 0.9),
        };
        let reqs = w.generate();
        assert!(reqs.len() > 20);
        let back = trace_from_json(&trace_to_json(&reqs)).unwrap();
        assert_eq!(reqs, back, "full field-for-field equality");
        // group ids (first shared token, high bit set by the generator)
        let groups = |rs: &[Request]| -> Vec<u32> {
            rs.iter()
                .map(|r| r.cache_tokens.first().copied().unwrap_or(0))
                .collect()
        };
        assert_eq!(groups(&reqs), groups(&back));
        for (a, b) in reqs.iter().zip(&back) {
            assert_eq!(a.cacheable_len(), b.cacheable_len());
            assert_eq!(a.cache_tokens, b.cache_tokens);
        }
    }

    #[test]
    fn trace_roundtrip_empty_and_long_context() {
        assert_eq!(
            trace_from_json(&trace_to_json(&[])).unwrap(),
            Vec::<Request>::new()
        );
        // LongBench prompts exercise the CACHE_TOKEN_CAP truncation path
        let w = WorkloadConfig::poisson(LengthProfile::LongBench, 1.0, 10.0, 22);
        let reqs = w.generate();
        let back = trace_from_json(&trace_to_json(&reqs)).unwrap();
        assert_eq!(reqs, back);
    }

    #[test]
    fn diurnal_envelope_peaks_midday_and_bursts_ride_on_top() {
        let p = ArrivalProcess::diurnal(10.0, 5.0, 100.0);
        // constructor fills in the default burst shape
        let (bf, bs, bp) = match p {
            ArrivalProcess::Diurnal {
                burst_factor,
                burst_secs,
                burst_period,
                ..
            } => (burst_factor, burst_secs, burst_period),
            _ => unreachable!(),
        };
        assert_eq!((bf, bs, bp), (1.5, 5.0, 25.0));
        assert_eq!(p.peak(), 10.0);
        // midnight trough = peak/ratio, but t=0 sits in a burst window
        assert!((p.rate_at(0.0) - 2.0 * 1.5).abs() < 1e-9, "{}", p.rate_at(0.0));
        // just past the burst window: bare trough-side envelope
        let early = p.rate_at(6.0);
        assert!(early < 3.0, "near-trough rate {early}");
        // midday (t=50) is outside bursts (50 % 25 = 0 is in-burst; use 56)
        let midday = p.rate_at(56.0);
        let evening = p.rate_at(80.0);
        assert!(midday > 9.0, "midday {midday}");
        assert!(evening < midday && evening > early, "evening {evening}");
        // thinning bound covers every instant
        for i in 0..1000 {
            let t = i as f64 * 0.1;
            assert!(p.rate_at(t) <= 10.0 * 1.5 + 1e-9);
        }
        // and the generated stream is denser midday than at night
        let mut rng = Rng::new(11);
        let times = p.arrivals(100.0, &mut rng);
        let mid = times.iter().filter(|t| (40.0..60.0).contains(*t)).count();
        let night = times.iter().filter(|t| (5.0..25.0).contains(*t)).count();
        assert!(
            mid > night,
            "diurnal density: midday {mid} vs night {night}"
        );
    }

    #[test]
    fn single_tenant_stream_is_byte_identical_to_legacy() {
        // tenants.n_tenants == 1 must not perturb any PRNG stream
        let base = cfg(20.0, 12).generate();
        let mut w = cfg(20.0, 12);
        w.tenants = TenantConfig {
            n_tenants: 1,
            zipf_s: 3.0, // skew irrelevant at one tenant
        };
        assert_eq!(base, w.generate());
    }

    #[test]
    fn tenants_partition_the_template_space() {
        let mut w = cfg(20.0, 13);
        w.prefix = PrefixConfig {
            share_prob: 1.0,
            n_templates: 2,
            zipf_s: 1.0,
            shared_frac: (0.5, 0.5),
        };
        w.tenants = TenantConfig {
            n_tenants: 8,
            zipf_s: 1.0, // near-uniform so several tenants appear
        };
        let reqs = w.generate();
        let mut firsts: Vec<u32> = reqs
            .iter()
            .filter(|r| !r.cache_tokens.is_empty())
            .map(|r| r.cache_tokens[0])
            .collect();
        firsts.sort_unstable();
        firsts.dedup();
        // more template groups than a single tenant could produce, but no
        // more than the global pool size
        assert!(firsts.len() > 2, "tenant mixing groups = {}", firsts.len());
        assert!(firsts.len() <= 16);
        // deterministic under the same seed
        assert_eq!(reqs, w.generate());
    }

    #[test]
    fn rate_at_reflects_burst_phase() {
        let p = ArrivalProcess::Bursty {
            rps: 2.0,
            burst_factor: 4.0,
            burst_secs: 5.0,
            period_secs: 20.0,
        };
        assert_eq!(p.rate_at(1.0), 8.0);
        assert_eq!(p.rate_at(6.0), 2.0);
        assert_eq!(p.rate_at(21.0), 8.0); // wraps
    }
}
