//! Simulated cluster substrate: GPU device specs, interconnect links, and
//! per-device runtime state (memory accounting + utilization tracking).
//!
//! The paper's testbed is A100 GPUs over NVLink/PCIe/IB; we model a device
//! as a (peak-FLOPs, HBM-capacity, HBM-bandwidth) triple and links as
//! (bandwidth, base-latency) pairs — exactly the quantities the paper's own
//! analytical models consume (Eqs 4, 11, 13, 27, 32).

use crate::util::stats::TimeWeighted;

/// Hardware description of one accelerator.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Peak dense fp16/bf16 FLOP/s.
    pub peak_flops: f64,
    /// HBM capacity in bytes.
    pub hbm_bytes: u64,
    /// HBM bandwidth in bytes/s.
    pub hbm_bw: f64,
    /// Relative serving capacity vs the A100-40G baseline (heterogeneous
    /// fleets: routers divide queue/load counters by this, so a 1.3x
    /// device absorbs 1.3x the work before looking equally loaded). The
    /// value tracks the roofline's bandwidth-bound decode ratio — see
    /// `perfmodel::relative_decode_capacity` and its pinning test.
    pub weight: f64,
    /// Relative price per device-second (autoscaler price/perf choice and
    /// the scenario cost accounting; A100-40G = 1.0).
    pub cost: f64,
}

/// NVIDIA A100-40GB (the paper's device; Fig 1 caption).
pub const A100_40G: GpuSpec = GpuSpec {
    name: "a100-40g",
    peak_flops: 312e12,
    hbm_bytes: 40_000_000_000,
    hbm_bw: 1.555e12,
    weight: 1.0,
    cost: 1.0,
};

/// NVIDIA A100-80GB.
pub const A100_80G: GpuSpec = GpuSpec {
    name: "a100-80g",
    peak_flops: 312e12,
    hbm_bytes: 80_000_000_000,
    hbm_bw: 2.039e12,
    // decode is bandwidth-bound: 2.039/1.555 ≈ 1.31x the 40G's capacity
    weight: 1.3,
    cost: 1.5,
};

/// Look up a built-in GPU spec by name (CLI `--gpu` / `--gpu-catalog`).
pub fn gpu_by_name(name: &str) -> Option<GpuSpec> {
    match name.to_ascii_lowercase().as_str() {
        "a100-40g" | "a100" | "40g" => Some(A100_40G),
        "a100-80g" | "80g" => Some(A100_80G),
        _ => None,
    }
}

/// Interconnect between devices / to the host-side KV store.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Link {
    /// Effective bandwidth, bytes/s.
    pub bandwidth: f64,
    /// Base (synchronization / setup) latency in seconds — the T_sync of Eq 4.
    pub latency: f64,
}

impl Link {
    /// Time to move `bytes` over this link (Eqs 4, 11, 13).
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        debug_assert!(
            self.bandwidth.is_finite() && self.bandwidth > 0.0,
            "Link bandwidth {} is degenerate — validate() at config time",
            self.bandwidth
        );
        self.latency + bytes as f64 / self.bandwidth
    }

    /// Reject degenerate link parameters at config time. A zero or
    /// non-finite bandwidth would make `transfer_time` return inf/NaN,
    /// which only the event queue's debug_assert would catch (and only in
    /// debug builds) — so config validation makes it a hard error instead.
    pub fn validate(&self, name: &str) -> Result<(), String> {
        if !(self.bandwidth.is_finite() && self.bandwidth > 0.0) {
            return Err(format!(
                "{name}: link bandwidth must be finite and > 0 (got {})",
                self.bandwidth
            ));
        }
        if !(self.latency.is_finite() && self.latency >= 0.0) {
            return Err(format!(
                "{name}: link latency must be finite and >= 0 (got {})",
                self.latency
            ));
        }
        Ok(())
    }
}

/// Live health of one device's uplink under transfer-plane fault
/// injection (`fault::FaultKind::LinkDegrade`/`LinkPartition`). Engines
/// keep one per device; the default is a perfectly healthy link, and the
/// nominal `slowdown` of 1.0 is an exact IEEE multiplicative identity —
/// healthy links charge byte-identical transfer times.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkHealth {
    /// Transfer-time multiplier (1.0 = nominal, >1 = degraded).
    pub slowdown: f64,
    /// True while the uplink is fully partitioned (no bytes move).
    pub partitioned: bool,
}

impl Default for LinkHealth {
    fn default() -> Self {
        LinkHealth {
            slowdown: 1.0,
            partitioned: false,
        }
    }
}

impl LinkHealth {
    pub fn healthy(&self) -> bool {
        self.slowdown == 1.0 && !self.partitioned
    }
}

/// Worst-case health over a transfer's two endpoints: the transfer runs
/// at the slower end's speed and is partitioned if either end is.
pub fn path_health(a: LinkHealth, b: LinkHealth) -> LinkHealth {
    LinkHealth {
        slowdown: a.slowdown.max(b.slowdown),
        partitioned: a.partitioned || b.partitioned,
    }
}

/// NVLink 3 (intra-node GPU<->GPU): ~300 GB/s effective, ~5 µs setup.
pub const NVLINK: Link = Link {
    bandwidth: 300e9,
    latency: 5e-6,
};

/// 200 Gbps fabric (the B = 200 Gbps of the paper's Eq 17): 25 GB/s.
pub const NET_200GBPS: Link = Link {
    bandwidth: 25e9,
    latency: 20e-6,
};

/// PCIe 4.0 x16 host link (CPU-tier KV store): ~25 GB/s practical.
pub const PCIE_GEN4: Link = Link {
    bandwidth: 25e9,
    latency: 10e-6,
};

/// What a device is currently serving (PD disaggregation role).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Role {
    /// Monolithic: both phases co-located (vLLM / HFT baselines).
    Unified,
    Prefill,
    Decode,
}

/// Fleet-membership lifecycle of a device (elastic scaling).
///
/// `Active` devices admit new work; `Draining` devices finish (or migrate
/// away) their residents but admit nothing new; `Released` devices have
/// been handed back and must never be touched again. The engines own the
/// Draining→Released transition (they know when residents are gone); the
/// autoscaler only ever requests Active→Draining and new Active devices.
///
/// `Failed` devices (fault injection) have crashed: they admit nothing,
/// their in-flight work is torn down by the engine, and — unlike
/// `Released` — they keep billing their cost until recovered, because a
/// crashed machine in a reservation is still paid for. `is_active()`
/// is false for Failed, so every routing/admission filter excludes them
/// automatically.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceState {
    Active,
    Draining,
    Released,
    Failed,
}

/// Runtime state of one simulated device.
#[derive(Debug, Clone)]
pub struct Device {
    pub id: usize,
    pub spec: GpuSpec,
    pub role: Role,
    /// Elastic-fleet membership (always `Active` for static fleets).
    pub state: DeviceState,
    /// Bytes of model weights resident (layer migration changes this).
    pub weight_bytes: u64,
    /// Bytes of KV cache currently allocated.
    pub kv_bytes: u64,
    /// Busy-fraction tracker (compute utilization over time).
    pub compute_util: TimeWeighted,
    /// Memory-utilization tracker (fraction of HBM in use over time).
    pub memory_util: TimeWeighted,
    /// Busy until this sim time (one outstanding step at a time).
    pub busy_until: f64,
    /// Straggler slowdown multiplier (fault injection): 1.0 = nominal;
    /// a 3.0 straggler takes 3x the modeled step time. Steps fold
    /// `straggle_overhead` into their completion timer, so the factor in
    /// effect at step START governs the whole step.
    pub slow_factor: f64,
}

impl Device {
    pub fn new(id: usize, spec: GpuSpec, role: Role) -> Self {
        Device {
            id,
            spec,
            role,
            state: DeviceState::Active,
            weight_bytes: 0,
            kv_bytes: 0,
            compute_util: TimeWeighted::new(),
            memory_util: TimeWeighted::new(),
            busy_until: 0.0,
            slow_factor: 1.0,
        }
    }

    /// Admitting new work? (Draining/Released devices only finish residents.)
    pub fn is_active(&self) -> bool {
        self.state == DeviceState::Active
    }

    pub fn mem_used(&self) -> u64 {
        self.weight_bytes + self.kv_bytes
    }

    pub fn mem_free(&self) -> u64 {
        self.spec.hbm_bytes.saturating_sub(self.mem_used())
    }

    /// Fraction of HBM in use — the M_d / M_d^max of Eq 32.
    pub fn mem_frac(&self) -> f64 {
        self.mem_used() as f64 / self.spec.hbm_bytes as f64
    }

    /// Record a memory change at sim time `now`.
    pub fn touch_mem(&mut self, now: f64) {
        let frac = self.mem_frac();
        self.memory_util.set(now, frac);
    }

    /// Record that the device is busy (1.0) or idle (0.0) from `now`.
    pub fn set_busy(&mut self, now: f64, busy: bool) {
        self.compute_util.set(now, if busy { 1.0 } else { 0.0 });
    }

    /// Can `bytes` of KV be allocated?
    pub fn can_fit_kv(&self, bytes: u64) -> bool {
        self.mem_free() >= bytes
    }

    /// Allocate KV bytes (caller must have checked `can_fit_kv`).
    pub fn alloc_kv(&mut self, now: f64, bytes: u64) {
        debug_assert!(self.can_fit_kv(bytes), "KV over-allocation");
        self.kv_bytes += bytes;
        self.touch_mem(now);
    }

    pub fn free_kv(&mut self, now: f64, bytes: u64) {
        debug_assert!(self.kv_bytes >= bytes, "KV double free");
        self.kv_bytes -= bytes;
        self.touch_mem(now);
    }

    /// Extra wall time a straggling device adds on top of a step's
    /// `nominal` modeled duration. Exactly 0.0 at the nominal factor, so
    /// healthy fleets (and fault-off runs) see bit-identical timers.
    pub fn straggle_overhead(&self, nominal: f64) -> f64 {
        (self.slow_factor - 1.0).max(0.0) * nominal
    }
}

// ---------------------------------------------------------------------------
// Shared device lifecycle (elastic fleets)
// ---------------------------------------------------------------------------
//
// The engines embed `devices: Vec<Device>` directly (they destructure a
// Cluster at construction), so the Active→Draining→Released state machine
// is expressed as free functions over `&mut [Device]`: one implementation
// serves `Cluster` AND every engine's inline device table, and the
// release-refusal invariant (never release while KV is resident) lives in
// exactly one place.

/// Begin draining device `id`: Active→Draining. Returns true when the
/// transition happened (no-op on already Draining/Released devices).
pub fn begin_drain(devices: &mut [Device], id: usize) -> bool {
    if devices[id].state == DeviceState::Active {
        devices[id].state = DeviceState::Draining;
        true
    } else {
        false
    }
}

/// Release a drained device once the engine reports its residents gone
/// (`residents_clear`: queues empty, no step in flight — only the engine
/// knows its worker topology). REFUSES while KV bytes are still resident:
/// releasing live state would corrupt memory accounting. Returns true when
/// the device is Released after the call (idempotent).
pub fn try_release(devices: &mut [Device], id: usize, residents_clear: bool) -> bool {
    let d = &mut devices[id];
    if d.state == DeviceState::Draining && residents_clear && d.kv_bytes == 0 {
        d.state = DeviceState::Released;
        true
    } else {
        d.state == DeviceState::Released
    }
}

/// Crash device `id` (fault injection): Active|Draining → Failed. The
/// engine must tear down its in-flight work (free KV, re-admit or count
/// sequences lost) — the state flip only stops admission. Returns true
/// when the transition happened (no-op on Released/already-Failed).
pub fn fail_device(devices: &mut [Device], id: usize) -> bool {
    match devices[id].state {
        DeviceState::Active | DeviceState::Draining => {
            devices[id].state = DeviceState::Failed;
            true
        }
        _ => false,
    }
}

/// Recover a crashed device: Failed → Active (a device that was Draining
/// when it crashed rejoins Active — the autoscaler will re-drain it if the
/// fleet is still oversized). Also resets any straggler slowdown. Returns
/// true when the transition happened.
pub fn recover_device(devices: &mut [Device], id: usize) -> bool {
    if devices[id].state == DeviceState::Failed {
        devices[id].state = DeviceState::Active;
        devices[id].slow_factor = 1.0;
        true
    } else {
        false
    }
}

/// Devices currently admitting work.
pub fn active_count(devices: &[Device]) -> usize {
    devices.iter().filter(|d| d.is_active()).count()
}

/// A cluster: devices plus the interconnect model.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub devices: Vec<Device>,
    /// GPU<->GPU link (weight / KV migration).
    pub gpu_link: Link,
    /// GPU<->host link (Global KV Cache Store tier).
    pub host_link: Link,
}

impl Cluster {
    /// Homogeneous cluster of `n` devices, all `role`.
    pub fn homogeneous(n: usize, spec: GpuSpec, role: Role) -> Self {
        Cluster {
            devices: (0..n).map(|i| Device::new(i, spec.clone(), role)).collect(),
            gpu_link: NVLINK,
            host_link: NET_200GBPS,
        }
    }

    /// PD-disaggregated cluster: `np` prefill + `nd` decode devices.
    pub fn pd_split(np: usize, nd: usize, spec: GpuSpec) -> Self {
        let mut devices = Vec::with_capacity(np + nd);
        for i in 0..np {
            devices.push(Device::new(i, spec.clone(), Role::Prefill));
        }
        for i in 0..nd {
            devices.push(Device::new(np + i, spec.clone(), Role::Decode));
        }
        Cluster {
            devices,
            gpu_link: NVLINK,
            host_link: NET_200GBPS,
        }
    }

    pub fn by_role(&self, role: Role) -> impl Iterator<Item = &Device> {
        self.devices.iter().filter(move |d| d.role == role)
    }

    pub fn ids_by_role(&self, role: Role) -> Vec<usize> {
        self.by_role(role).map(|d| d.id).collect()
    }

    // --- elastic fleet (runtime scale-out / drain) -------------------------
    //
    // Thin wrappers over the shared lifecycle free functions above — the
    // engines call those functions directly on their own device tables.

    /// Add a device to the running cluster. Device ids are stable (indices
    /// into `devices`), so released slots are never reused — a new device
    /// always gets a fresh id at the end of the table.
    pub fn add_device(&mut self, spec: GpuSpec, role: Role) -> usize {
        let id = self.devices.len();
        self.devices.push(Device::new(id, spec, role));
        id
    }

    /// Begin draining a device: it stops admitting new work. The engine
    /// must finish (or migrate away) its residents, then call
    /// [`Cluster::release_device`]. No-op on already Draining/Released.
    pub fn drain_device(&mut self, id: usize) {
        begin_drain(&mut self.devices, id);
    }

    /// Release a drained device. Refuses (returns false) while KV is still
    /// resident — releasing live state would corrupt memory accounting.
    pub fn release_device(&mut self, id: usize) -> bool {
        try_release(&mut self.devices, id, true)
    }

    /// Devices currently admitting work.
    pub fn active_count(&self) -> usize {
        active_count(&self.devices)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_transfer_time_eq4() {
        // Eq 4 shape: payload / bandwidth + latency
        let l = Link {
            bandwidth: 100.0,
            latency: 0.5,
        };
        assert!((l.transfer_time(1000) - 10.5).abs() < 1e-12);
        assert_eq!(l.transfer_time(0), 0.5);
    }

    #[test]
    fn net_200gbps_matches_paper_eq17() {
        // Eq 17: 4 KB * 1000 * 0.5 over 200 Gbps ≈ 0.082 ms (paper's number,
        // which uses the decimal-GB convention 200e9/8 = 25e9 B/s).
        let bytes = (4096.0_f64 * 1000.0 * 0.5) as u64;
        let t = bytes as f64 / NET_200GBPS.bandwidth;
        assert!((t - 0.082e-3).abs() < 0.003e-3, "t = {t:.6}");
    }

    #[test]
    fn device_memory_accounting() {
        let mut d = Device::new(0, A100_40G, Role::Decode);
        d.weight_bytes = 10_000_000_000;
        assert_eq!(d.mem_free(), 30_000_000_000);
        assert!(d.can_fit_kv(30_000_000_000));
        assert!(!d.can_fit_kv(30_000_000_001));
        d.alloc_kv(1.0, 5_000_000_000);
        assert_eq!(d.kv_bytes, 5_000_000_000);
        d.free_kv(2.0, 5_000_000_000);
        assert_eq!(d.kv_bytes, 0);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn kv_double_free_panics_in_debug() {
        let mut d = Device::new(0, A100_40G, Role::Decode);
        d.free_kv(0.0, 1);
    }

    #[test]
    fn utilization_tracking_time_weighted() {
        let mut d = Device::new(0, A100_40G, Role::Prefill);
        d.set_busy(0.0, true);
        d.set_busy(3.0, false);
        d.set_busy(4.0, false);
        // busy 3s of 4s
        assert!((d.compute_util.average(4.0) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn pd_split_roles() {
        let c = Cluster::pd_split(2, 3, A100_40G);
        assert_eq!(c.devices.len(), 5);
        assert_eq!(c.ids_by_role(Role::Prefill), vec![0, 1]);
        assert_eq!(c.ids_by_role(Role::Decode), vec![2, 3, 4]);
    }

    #[test]
    fn homogeneous_cluster_unified() {
        let c = Cluster::homogeneous(3, A100_80G, Role::Unified);
        assert_eq!(c.by_role(Role::Unified).count(), 3);
        assert_eq!(c.by_role(Role::Prefill).count(), 0);
    }

    #[test]
    fn elastic_add_drain_release_lifecycle() {
        let mut c = Cluster::pd_split(1, 1, A100_40G);
        assert_eq!(c.active_count(), 2);
        let id = c.add_device(A100_40G, Role::Decode);
        assert_eq!(id, 2);
        assert_eq!(c.devices[id].state, DeviceState::Active);
        assert_eq!(c.active_count(), 3);

        c.drain_device(id);
        assert_eq!(c.devices[id].state, DeviceState::Draining);
        assert_eq!(c.active_count(), 2);

        // refuse release while KV is resident
        c.devices[id].kv_bytes = 64;
        assert!(!c.release_device(id));
        assert_eq!(c.devices[id].state, DeviceState::Draining);
        c.devices[id].kv_bytes = 0;
        assert!(c.release_device(id));
        assert_eq!(c.devices[id].state, DeviceState::Released);
        // idempotent
        assert!(c.release_device(id));
        // draining a released device is a no-op
        c.drain_device(id);
        assert_eq!(c.devices[id].state, DeviceState::Released);
    }

    #[test]
    fn lifecycle_free_functions_enforce_release_refusal() {
        // the shared &mut [Device] functions the engines call directly
        let mut devs = vec![
            Device::new(0, A100_40G, Role::Prefill),
            Device::new(1, A100_40G, Role::Decode),
        ];
        assert_eq!(active_count(&devs), 2);
        assert!(begin_drain(&mut devs, 1));
        assert!(!begin_drain(&mut devs, 1), "double drain is a no-op");
        assert_eq!(active_count(&devs), 1);
        // refuse while the engine still reports residents
        assert!(!try_release(&mut devs, 1, false));
        // refuse while KV is resident even if the engine says clear
        devs[1].kv_bytes = 64;
        assert!(!try_release(&mut devs, 1, true));
        assert_eq!(devs[1].state, DeviceState::Draining);
        devs[1].kv_bytes = 0;
        assert!(try_release(&mut devs, 1, true));
        assert_eq!(devs[1].state, DeviceState::Released);
        assert!(try_release(&mut devs, 1, true), "release is idempotent");
        // an Active device never releases through this path
        assert!(!try_release(&mut devs, 0, true));
        assert_eq!(devs[0].state, DeviceState::Active);
    }

    #[test]
    fn new_devices_get_fresh_stable_ids() {
        let mut c = Cluster::homogeneous(2, A100_80G, Role::Unified);
        c.drain_device(1);
        c.release_device(1);
        let id = c.add_device(A100_80G, Role::Unified);
        assert_eq!(id, 2, "released slots are never reused");
        assert_eq!(c.devices[2].id, 2);
    }

    #[test]
    fn gpu_by_name_resolves_catalog_specs() {
        assert_eq!(gpu_by_name("a100-40g"), Some(A100_40G));
        assert_eq!(gpu_by_name("80G"), Some(A100_80G));
        assert_eq!(gpu_by_name("h100"), None);
        assert_eq!(A100_40G.weight, 1.0, "the baseline defines weight 1.0");
        assert_eq!(A100_40G.cost, 1.0, "the baseline defines cost 1.0");
        assert!(A100_80G.weight > 1.0 && A100_80G.cost > 1.0);
    }

    #[test]
    fn link_validate_rejects_degenerate_parameters() {
        assert!(NVLINK.validate("nvlink").is_ok());
        assert!(NET_200GBPS.validate("net").is_ok());
        assert!(PCIE_GEN4.validate("pcie").is_ok());
        let zero_bw = Link { bandwidth: 0.0, latency: 1e-6 };
        assert!(zero_bw.validate("z").unwrap_err().contains("bandwidth"));
        let nan_bw = Link { bandwidth: f64::NAN, latency: 1e-6 };
        assert!(nan_bw.validate("n").is_err());
        let inf_bw = Link { bandwidth: f64::INFINITY, latency: 1e-6 };
        assert!(inf_bw.validate("i").is_err());
        let neg_lat = Link { bandwidth: 1e9, latency: -1.0 };
        assert!(neg_lat.validate("l").unwrap_err().contains("latency"));
        let nan_lat = Link { bandwidth: 1e9, latency: f64::NAN };
        assert!(nan_lat.validate("l").is_err());
    }

    #[test]
    fn fail_recover_lifecycle() {
        let mut devs = vec![
            Device::new(0, A100_40G, Role::Unified),
            Device::new(1, A100_40G, Role::Unified),
        ];
        assert!(fail_device(&mut devs, 1));
        assert_eq!(devs[1].state, DeviceState::Failed);
        assert!(!devs[1].is_active(), "Failed must not admit work");
        assert_eq!(active_count(&devs), 1);
        assert!(!fail_device(&mut devs, 1), "double crash is a no-op");
        // a Failed device cannot be drained or released
        assert!(!begin_drain(&mut devs, 1));
        assert!(!try_release(&mut devs, 1, true));
        assert_eq!(devs[1].state, DeviceState::Failed);
        devs[1].slow_factor = 3.0;
        assert!(recover_device(&mut devs, 1));
        assert_eq!(devs[1].state, DeviceState::Active);
        assert_eq!(devs[1].slow_factor, 1.0, "recovery clears slowdown");
        assert!(!recover_device(&mut devs, 1), "recover is Failed-only");
        // a Draining device that crashes recovers straight to Active
        assert!(begin_drain(&mut devs, 0));
        assert!(fail_device(&mut devs, 0));
        assert!(recover_device(&mut devs, 0));
        assert_eq!(devs[0].state, DeviceState::Active);
        // a Released device never fails (it is gone)
        assert!(begin_drain(&mut devs, 0));
        assert!(try_release(&mut devs, 0, true));
        assert!(!fail_device(&mut devs, 0));
        assert_eq!(devs[0].state, DeviceState::Released);
    }

    #[test]
    fn straggle_overhead_is_zero_at_nominal_factor() {
        let mut d = Device::new(0, A100_40G, Role::Unified);
        assert_eq!(d.straggle_overhead(0.25), 0.0);
        d.slow_factor = 3.0;
        assert!((d.straggle_overhead(0.25) - 0.5).abs() < 1e-12);
        d.slow_factor = 0.5; // a "fast" factor never shortens a step
        assert_eq!(d.straggle_overhead(0.25), 0.0);
    }

    #[test]
    fn mem_frac_in_unit_range() {
        let mut d = Device::new(0, A100_40G, Role::Decode);
        d.weight_bytes = 20_000_000_000;
        assert!((d.mem_frac() - 0.5).abs() < 1e-9);
    }
}
