//! Engine-level fault-injection suite (PR 6):
//!
//! * **Replay determinism** — the same seed replays a byte-identical
//!   fault schedule and a byte-identical `Report` for every engine.
//! * **Zero cost when off** — with `fault.enabled = false`, the other
//!   fault knobs are never read: scrambling them changes nothing in the
//!   output, byte for byte.
//! * **Conservation under fire** — with aggressive crash rates and a
//!   tiny retry budget, `run_experiment`'s internal
//!   `submitted = completed + dropped + lost + inflight` check must hold
//!   for all four engines, and the fault counters must show the chaos
//!   layer actually engaged.
//! * **Store rescue** — BanaServe's Global-KV-Store recovery path fires
//!   (recovered sequences observed) on a shared-prefix workload under
//!   crashes.
//!
//! Plus the PR 8 transfer-plane suite:
//!
//! * degraded runs (link flaps + store-node crashes) replay
//!   byte-identically from the same seed for every engine,
//! * conservation holds for all four engines under aggressive link
//!   partitions, with the link fault counters proving the chaos engaged.

use banaserve::config::{EngineKind, ExperimentConfig};
use banaserve::engines::{run_experiment, ExperimentOutcome};
use banaserve::workload::{LengthProfile, WorkloadConfig};

const ALL_ENGINES: [EngineKind; 4] = [
    EngineKind::HfStatic,
    EngineKind::Vllm,
    EngineKind::DistServe,
    EngineKind::BanaServe,
];

fn base_cfg(kind: EngineKind, rps: f64, seed: u64) -> ExperimentConfig {
    let mut c = ExperimentConfig::default_for(kind, "llama-13b", rps, seed);
    c.workload = WorkloadConfig::poisson(LengthProfile::AlpacaShort, rps, 30.0, seed);
    c.warmup = 0.0;
    c.n_devices = 6;
    c.n_prefill = 3;
    c
}

fn faulty_cfg(kind: EngineKind, seed: u64) -> ExperimentConfig {
    let mut c = base_cfg(kind, 8.0, seed);
    c.fault.enabled = true;
    c.fault.crash_mtbf = 3.0;
    c.fault.recovery_time = 2.0;
    c.fault.straggler_prob = 0.4;
    c.fault.straggler_factor = 3.0;
    c.fault.straggler_secs = 2.0;
    c.fault.retry_budget = 1;
    c.fault.retry_backoff = 0.1;
    c
}

/// Device crashes plus the PR 8 transfer-plane chaos: link flaps with a
/// high partition share, and store-node crashes over a 3-shard store.
fn degraded_cfg(kind: EngineKind, seed: u64) -> ExperimentConfig {
    let mut c = faulty_cfg(kind, seed);
    c.fault.crash_mtbf = 8.0;
    c.fault.retry_budget = 3;
    c.fault.link_mtbf = 2.0;
    c.fault.link_partition_prob = 1.0;
    c.fault.link_fault_secs = 2.0;
    c.fault.store_crash_mtbf = 5.0;
    c.bana.store_nodes = 3;
    c.bana.store_replication = 2;
    c.workload.prefix.share_prob = 0.6;
    c
}

/// A deterministic fingerprint of everything a run reports. `Report` and
/// the extras are plain data with `Debug` derives, so the dump is a full
/// byte-for-byte witness of the outcome.
fn fingerprint(out: &ExperimentOutcome) -> String {
    format!(
        "{:?} | {:?} | {:?}",
        out.report, out.device_util, out.extras
    )
}

#[test]
fn same_seed_replays_an_identical_faulty_run() {
    for kind in ALL_ENGINES {
        let a = run_experiment(&faulty_cfg(kind, 42));
        let b = run_experiment(&faulty_cfg(kind, 42));
        assert_eq!(
            fingerprint(&a),
            fingerprint(&b),
            "{:?}: same seed must replay the same faults and the same report",
            kind
        );
    }
}

#[test]
fn fault_knobs_are_inert_while_disabled() {
    for kind in ALL_ENGINES {
        let clean = run_experiment(&base_cfg(kind, 8.0, 7));
        // scramble every knob except the master switch: none of them may
        // be read on any code path while the layer is off
        let mut scrambled = base_cfg(kind, 8.0, 7);
        scrambled.fault.crash_mtbf = 0.5;
        scrambled.fault.recovery_time = 99.0;
        scrambled.fault.straggler_prob = 1.0;
        scrambled.fault.straggler_factor = 10.0;
        scrambled.fault.straggler_secs = 30.0;
        scrambled.fault.retry_budget = 0;
        scrambled.fault.retry_backoff = 5.0;
        // PR 8 transfer-plane knobs ride the same master switch. (The
        // sharded-store *topology* knobs — bana.store_nodes / replication —
        // are deliberately not scrambled: shard placement changes behavior
        // even with a perfectly healthy store.)
        scrambled.fault.link_mtbf = 2.0;
        scrambled.fault.link_degrade_factor = 16.0;
        scrambled.fault.link_partition_prob = 1.0;
        scrambled.fault.link_fault_secs = 9.0;
        scrambled.fault.store_crash_mtbf = 1.0;
        scrambled.fault.transfer_timeout_factor = 1.1;
        scrambled.fault.transfer_retries = 0;
        let off = run_experiment(&scrambled);
        assert_eq!(
            fingerprint(&clean),
            fingerprint(&off),
            "{:?}: disabled fault layer must be invisible in the output",
            kind
        );
        assert_eq!(clean.extras.crashes, 0);
        assert_eq!(clean.report.lost, 0);
    }
}

#[test]
fn conservation_holds_under_aggressive_faults() {
    // run_experiment panics if submitted != completed + dropped + lost +
    // inflight, so reaching the asserts below IS the conservation check
    for kind in ALL_ENGINES {
        for seed in [3, 11] {
            let out = run_experiment(&faulty_cfg(kind, seed));
            assert!(
                out.report.n_requests > 0,
                "{:?} seed {seed}: no requests completed under faults",
                kind
            );
            assert!(
                out.extras.crashes + out.extras.stragglers > 0,
                "{:?} seed {seed}: chaos layer never engaged \
                 (crashes={}, stragglers={})",
                kind,
                out.extras.crashes,
                out.extras.stragglers
            );
        }
    }
}

#[test]
fn crashes_force_retries_and_budget_overruns_are_lost_not_leaked() {
    // with a zero retry budget every crashed sequence is lost on first
    // teardown — loss must be visible in the report and still conserve
    let mut any_lost = false;
    for kind in ALL_ENGINES {
        let mut c = faulty_cfg(kind, 5);
        c.fault.straggler_prob = 0.0; // crashes only
        c.fault.retry_budget = 0;
        let out = run_experiment(&c);
        if out.extras.crashes > 0 && out.report.lost > 0 {
            any_lost = true;
        }
    }
    assert!(
        any_lost,
        "no engine recorded lost requests despite zero retry budget"
    );
}

#[test]
fn same_seed_replays_an_identical_degraded_run() {
    // link flaps, partitions and store-node crashes all ride seeded
    // substreams — a degraded run must replay byte-for-byte, or scenario
    // cells comparing replication settings lose their paired schedules
    for kind in ALL_ENGINES {
        let a = run_experiment(&degraded_cfg(kind, 13));
        let b = run_experiment(&degraded_cfg(kind, 13));
        assert_eq!(
            fingerprint(&a),
            fingerprint(&b),
            "{:?}: same seed must replay the same degraded run",
            kind
        );
    }
}

#[test]
fn conservation_holds_under_aggressive_link_partitions() {
    // run_experiment panics if submitted != completed + dropped + lost +
    // inflight, so completing these runs IS the conservation check; the
    // counters then prove the transfer plane actually engaged
    let mut timeouts_or_retries = 0u64;
    for kind in ALL_ENGINES {
        for seed in [3, 11] {
            let out = run_experiment(&degraded_cfg(kind, seed));
            assert!(
                out.report.n_requests > 0,
                "{:?} seed {seed}: nothing completed under link partitions",
                kind
            );
            assert!(
                out.extras.link_degradations > 0,
                "{:?} seed {seed}: no link episodes were applied",
                kind
            );
            timeouts_or_retries +=
                out.extras.transfer_timeouts + out.extras.transfer_retries;
            if kind == EngineKind::BanaServe {
                assert!(
                    out.extras.store_node_crashes > 0,
                    "seed {seed}: no store-node crashes engaged",
                );
            }
        }
    }
    assert!(
        timeouts_or_retries > 0,
        "no engine ever timed out or retried a transfer despite \
         guaranteed partitions"
    );
}

#[test]
fn banaserve_store_rescue_recovers_crashed_sequences() {
    let mut c = faulty_cfg(EngineKind::BanaServe, 9);
    c.fault.straggler_prob = 0.0;
    c.fault.retry_budget = 5;
    c.workload.prefix.share_prob = 0.8;
    let out = run_experiment(&c);
    assert!(out.extras.crashes > 0, "no crashes engaged");
    assert!(
        out.extras.recovered_seqs > 0,
        "store rescue never re-admitted a crashed sequence \
         (crashes={}, retries={})",
        out.extras.crashes,
        out.extras.retries
    );
}
