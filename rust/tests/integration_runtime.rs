//! Runtime integration: the rust PJRT path must reproduce the numbers the
//! python (JAX + Pallas) side computed at AOT time — the cross-layer
//! correctness contract of the three-layer architecture.
//!
//! Requires `make artifacts` (the Makefile test target guarantees it) and
//! the `pjrt` feature (xla bindings).
#![cfg(feature = "pjrt")]

use banaserve::runtime::{argmax, EntryKind, Golden, KvCache, Manifest, Runtime};

const DIR: &str = "artifacts";

fn runtime() -> Runtime {
    Runtime::load(DIR, "tiny").expect("run `make artifacts` first")
}

#[test]
fn manifest_parses_and_lists_entries() {
    let m = Manifest::load(DIR).unwrap();
    let (cfg, entries) = m.variant("tiny").unwrap();
    assert_eq!(cfg.vocab, 256);
    assert_eq!(cfg.n_layers, 2);
    assert!(entries.len() >= 4);
    assert!(entries
        .iter()
        .any(|e| e.kind == EntryKind::Prefill && e.batch == 1));
    assert!(entries
        .iter()
        .any(|e| e.kind == EntryKind::Decode && e.batch == 4));
}

#[test]
fn prefill_matches_python_golden_logits() {
    let rt = runtime();
    let golden = Golden::load(DIR, "tiny").unwrap();
    let (vcfg, _) = rt.manifest.variant("tiny").unwrap();
    let vocab = vcfg.vocab;
    let entry = rt.find_entry(EntryKind::Prefill, 1).unwrap();
    let s = entry.meta.seq;
    let mut toks = golden.prompt.clone();
    assert!(toks.len() <= s);
    let plen = toks.len();
    toks.resize(s, 0);
    let (logits, _k, _v) = rt.prefill(entry, &toks).unwrap();
    let row = &logits[(plen - 1) * vocab..plen * vocab];
    for (i, (&got, &want)) in row
        .iter()
        .zip(golden.prefill_logits_first4.iter())
        .take(4)
        .enumerate()
    {
        assert!(
            (got - want).abs() < 1e-3,
            "logit {i}: rust {got} vs python {want}"
        );
    }
}

#[test]
fn greedy_decode_matches_python_golden_tokens() {
    // full autoregressive loop through PJRT must reproduce the python
    // greedy continuation token-for-token.
    let rt = runtime();
    let golden = Golden::load(DIR, "tiny").unwrap();
    let (vcfg, _) = rt.manifest.variant("tiny").unwrap();
    let vcfg = vcfg.clone();
    let prefill = rt.find_entry(EntryKind::Prefill, 1).unwrap();
    let decode = rt.find_entry(EntryKind::Decode, 1).unwrap();

    let plen = golden.prompt.len();
    let mut toks = golden.prompt.clone();
    toks.resize(prefill.meta.seq, 0);
    let (logits, kc, vc) = rt.prefill(prefill, &toks).unwrap();
    let mut cache = KvCache::zeros(&vcfg, 1);
    cache.write_prefix(0, &kc, &vc, prefill.meta.seq);

    let row = &logits[(plen - 1) * vcfg.vocab..plen * vcfg.vocab];
    let mut cur = argmax(row) as i32;
    let mut cur_len = plen as i32;
    let mut generated = Vec::new();
    for _ in 0..golden.generated.len() {
        generated.push(cur);
        let lg = rt
            .decode_step(decode, &[cur], &[cur_len], &mut cache)
            .unwrap();
        cur = argmax(&lg[..vcfg.vocab]) as i32;
        cur_len += 1;
    }
    assert_eq!(generated, golden.generated, "greedy continuation diverged");
}

#[test]
fn batched_decode_rows_are_independent() {
    // two different prompts in a b4 batch must each match their b1 runs.
    let rt = runtime();
    let (vcfg, _) = rt.manifest.variant("tiny").unwrap();
    let vcfg = vcfg.clone();
    let prefill = rt.find_entry(EntryKind::Prefill, 1).unwrap();
    let decode1 = rt.find_entry(EntryKind::Decode, 1).unwrap();
    let decode4 = rt.find_entry(EntryKind::Decode, 4).unwrap();

    let prompts: Vec<Vec<i32>> = vec![(1..9).collect(), (40..52).collect()];
    // independent b1 references
    let mut refs = Vec::new();
    for p in &prompts {
        let mut toks = p.clone();
        toks.resize(prefill.meta.seq, 0);
        let (logits, kc, vc) = rt.prefill(prefill, &toks).unwrap();
        let mut cache = KvCache::zeros(&vcfg, 1);
        cache.write_prefix(0, &kc, &vc, prefill.meta.seq);
        let mut cur =
            argmax(&logits[(p.len() - 1) * vcfg.vocab..p.len() * vcfg.vocab]) as i32;
        let mut cur_len = p.len() as i32;
        let mut gen = Vec::new();
        for _ in 0..5 {
            gen.push(cur);
            let lg = rt
                .decode_step(decode1, &[cur], &[cur_len], &mut cache)
                .unwrap();
            cur = argmax(&lg[..vcfg.vocab]) as i32;
            cur_len += 1;
        }
        refs.push(gen);
    }
    // batched run: slots 0,1 hold the prompts; 2,3 idle
    let mut cache = KvCache::zeros(&vcfg, 4);
    let mut curs = [0i32; 4];
    let mut lens = [0i32; 4];
    for (i, p) in prompts.iter().enumerate() {
        let mut toks = p.clone();
        toks.resize(prefill.meta.seq, 0);
        let (logits, kc, vc) = rt.prefill(prefill, &toks).unwrap();
        cache.write_prefix(i, &kc, &vc, prefill.meta.seq);
        curs[i] = argmax(&logits[(p.len() - 1) * vcfg.vocab..p.len() * vcfg.vocab]) as i32;
        lens[i] = p.len() as i32;
    }
    let mut gens: Vec<Vec<i32>> = vec![Vec::new(); 2];
    for _ in 0..5 {
        for i in 0..2 {
            gens[i].push(curs[i]);
        }
        let lg = rt.decode_step(decode4, &curs, &lens, &mut cache).unwrap();
        for i in 0..2 {
            curs[i] = argmax(&lg[i * vcfg.vocab..(i + 1) * vcfg.vocab]) as i32;
            lens[i] += 1;
        }
        for i in 2..4 {
            lens[i] += 1; // idle slots advance; outputs ignored
        }
    }
    assert_eq!(gens[0], refs[0], "slot 0 diverged from b1 reference");
    assert_eq!(gens[1], refs[1], "slot 1 diverged from b1 reference");
}

#[test]
fn kv_slot_migration_preserves_generation() {
    // extract a sequence's KV slot mid-generation, install it in a fresh
    // cache (the runtime analog of BanaServe's KV migration), continue —
    // the continuation must be identical.
    let rt = runtime();
    let (vcfg, _) = rt.manifest.variant("tiny").unwrap();
    let vcfg = vcfg.clone();
    let prefill = rt.find_entry(EntryKind::Prefill, 1).unwrap();
    let decode = rt.find_entry(EntryKind::Decode, 1).unwrap();

    let prompt: Vec<i32> = (10..26).collect();
    let mut toks = prompt.clone();
    toks.resize(prefill.meta.seq, 0);
    let (logits, kc, vc) = rt.prefill(prefill, &toks).unwrap();
    let mut cache = KvCache::zeros(&vcfg, 1);
    cache.write_prefix(0, &kc, &vc, prefill.meta.seq);
    let mut cur = argmax(
        &logits[(prompt.len() - 1) * vcfg.vocab..prompt.len() * vcfg.vocab],
    ) as i32;
    let mut cur_len = prompt.len() as i32;
    for _ in 0..3 {
        let lg = rt
            .decode_step(decode, &[cur], &[cur_len], &mut cache)
            .unwrap();
        cur = argmax(&lg[..vcfg.vocab]) as i32;
        cur_len += 1;
    }
    // un-migrated continuation (reference)
    let mut ref_cache = cache.clone();
    let mut ref_cur = cur;
    let mut ref_len = cur_len;
    let mut want = Vec::new();
    for _ in 0..4 {
        let lg = rt
            .decode_step(decode, &[ref_cur], &[ref_len], &mut ref_cache)
            .unwrap();
        ref_cur = argmax(&lg[..vcfg.vocab]) as i32;
        ref_len += 1;
        want.push(ref_cur);
    }
    // migrate: extract + install into a fresh "cold device" cache
    let (ks, vs) = cache.extract_slot(0);
    let mut cold = KvCache::zeros(&vcfg, 1);
    cold.install_slot(0, &ks, &vs);
    let mut got = Vec::new();
    for _ in 0..4 {
        let lg = rt
            .decode_step(decode, &[cur], &[cur_len], &mut cold)
            .unwrap();
        cur = argmax(&lg[..vcfg.vocab]) as i32;
        cur_len += 1;
        got.push(cur);
    }
    assert_eq!(got, want, "migrated continuation diverged");
}
