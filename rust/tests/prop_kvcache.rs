//! Property tests for the KV-cache substrate (paged allocator, radix tree,
//! global store) via the in-repo checker harness (proptest is not in the
//! offline registry). Seeds are reported on failure; replay with
//! BANASERVE_PROP_SEED=<hex>.

use banaserve::kvcache::{BlockAllocator, GlobalKvStore, RadixTree, SeqBlocks, StoreConfig};
use banaserve::model::LLAMA31_8B;
use banaserve::prop_assert;
use banaserve::util::checker::check;

#[test]
fn allocator_conserves_blocks_under_random_ops() {
    check("alloc conservation", 60, |g| {
        let total = g.usize_in(4, 64) as u32;
        let mut a = BlockAllocator::new(total, 16);
        let mut live: Vec<u32> = Vec::new();
        for _ in 0..g.usize_in(10, 200) {
            match g.usize_in(0, 2) {
                0 => {
                    if let Some(b) = a.alloc() {
                        live.push(b);
                    }
                }
                1 => {
                    if !live.is_empty() {
                        let i = g.usize_in(0, live.len() - 1);
                        let b = live.swap_remove(i);
                        a.decref(b);
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let i = g.usize_in(0, live.len() - 1);
                        let b = live[i];
                        a.incref(b);
                        live.push(b); // one live handle per ref
                    }
                }
            }
            prop_assert!(
                a.used_blocks() + a.free_blocks() == a.total_blocks(),
                "used {} + free {} != total {}",
                a.used_blocks(),
                a.free_blocks(),
                a.total_blocks()
            );
        }
        // release everything: pool must be whole again
        for b in live {
            a.decref(b);
        }
        prop_assert!(
            a.free_blocks() == total,
            "leak: {} of {} free after full release",
            a.free_blocks(),
            total
        );
        Ok(())
    });
}

#[test]
fn seq_blocks_never_leak_on_failed_append() {
    check("seq append no-leak", 40, |g| {
        let total = g.usize_in(2, 12) as u32;
        let mut a = BlockAllocator::new(total, 16);
        let mut seqs: Vec<SeqBlocks> = (0..g.usize_in(1, 4)).map(|_| SeqBlocks::new()).collect();
        for _ in 0..g.usize_in(5, 60) {
            let i = g.usize_in(0, seqs.len() - 1);
            let n = g.usize_in(1, 40) as u64;
            let before_free = a.free_blocks();
            let before_tokens = seqs[i].tokens;
            if !seqs[i].append(&mut a, n) {
                prop_assert!(
                    a.free_blocks() == before_free && seqs[i].tokens == before_tokens,
                    "failed append mutated state"
                );
            }
        }
        for s in seqs.iter_mut() {
            s.release(&mut a);
        }
        prop_assert!(a.free_blocks() == total, "blocks leaked");
        Ok(())
    });
}

/// Naive oracle: longest common prefix against every stored sequence.
fn naive_match(stored: &[Vec<u32>], q: &[u32]) -> u64 {
    stored
        .iter()
        .map(|s| {
            s.iter()
                .zip(q.iter())
                .take_while(|(a, b)| a == b)
                .count() as u64
        })
        .max()
        .unwrap_or(0)
}

#[test]
fn radix_matches_naive_prefix_oracle() {
    check("radix vs naive", 50, |g| {
        let mut t = RadixTree::new();
        let mut stored: Vec<Vec<u32>> = Vec::new();
        let vocab = g.rng.range(2, 8);
        for _ in 0..g.usize_in(1, 20) {
            let s = g.tokens(24, vocab);
            if s.is_empty() {
                continue;
            }
            t.insert(&s);
            stored.push(s);
        }
        for _ in 0..20 {
            let q = g.tokens(30, vocab);
            let got = t.peek_prefix(&q);
            let want = naive_match(&stored, &q);
            prop_assert!(got == want, "query {q:?}: radix {got} vs naive {want}");
        }
        Ok(())
    });
}

#[test]
fn radix_token_count_equals_unique_prefix_mass() {
    // inserting the same sequences in any order yields the same count
    check("radix count order-independent", 40, |g| {
        let vocab = g.rng.range(2, 5);
        let seqs: Vec<Vec<u32>> = (0..g.usize_in(2, 10))
            .map(|_| g.tokens(16, vocab))
            .filter(|s| !s.is_empty())
            .collect();
        let mut t1 = RadixTree::new();
        for s in &seqs {
            t1.insert(s);
        }
        let mut rev = seqs.clone();
        rev.reverse();
        let mut t2 = RadixTree::new();
        for s in &rev {
            t2.insert(s);
        }
        prop_assert!(
            t1.token_count() == t2.token_count(),
            "order-dependent token count: {} vs {}",
            t1.token_count(),
            t2.token_count()
        );
        Ok(())
    });
}

#[test]
fn radix_eviction_preserves_matching_correctness() {
    check("radix evict correctness", 40, |g| {
        let mut t = RadixTree::new();
        let vocab = g.rng.range(2, 6);
        let mut stored: Vec<Vec<u32>> = Vec::new();
        for _ in 0..g.usize_in(3, 15) {
            let s = g.tokens(20, vocab);
            if s.is_empty() {
                continue;
            }
            t.insert(&s);
            stored.push(s);
        }
        let budget = g.rng.range(0, t.token_count().max(1));
        t.evict_to(budget);
        prop_assert!(t.token_count() <= budget, "over budget after evict");
        // matches can only shrink, never report phantom tokens: a peek must
        // never exceed the naive oracle over the ORIGINAL set
        for q in &stored {
            let got = t.peek_prefix(q);
            let want = naive_match(&stored, q);
            prop_assert!(got <= want, "phantom prefix after eviction");
        }
        Ok(())
    });
}

#[test]
fn radix_stress_invariants_under_churn() {
    // thousands of random insert/match/evict ops; after every op the tree's
    // structural invariants must hold: token_count == sum of live segments,
    // LRU contains exactly the evictable leaves in access order, freed arena
    // slots are disjoint from the live tree.
    check("radix churn invariants", 20, |g| {
        let mut t = RadixTree::new();
        let vocab = g.rng.range(2, 12);
        let ops = g.usize_in(100, 400);
        for _ in 0..ops {
            match g.usize_in(0, 5) {
                0 | 1 => {
                    let s = g.tokens(24, vocab);
                    if !s.is_empty() {
                        t.insert(&s);
                    }
                }
                2 => {
                    let q = g.tokens(24, vocab);
                    t.match_prefix(&q);
                }
                3 => {
                    let budget = g.rng.range(0, t.token_count().max(1));
                    t.evict_to(budget);
                    prop_assert!(
                        t.token_count() <= budget,
                        "over budget: {} > {budget}",
                        t.token_count()
                    );
                }
                4 => {
                    // tier demotion: hot mass moves to SSD, nothing is lost
                    let before = t.token_count();
                    let hot_budget = g.rng.range(0, t.hot_tokens().max(1));
                    t.demote_to(hot_budget);
                    prop_assert!(
                        t.token_count() == before,
                        "demotion changed residency: {} -> {}",
                        before,
                        t.token_count()
                    );
                }
                _ => {
                    let cold_budget = g.rng.range(0, t.cold_tokens().max(1));
                    t.evict_cold_to(cold_budget);
                    prop_assert!(
                        t.cold_tokens() <= cold_budget,
                        "cold tier over budget: {} > {cold_budget}",
                        t.cold_tokens()
                    );
                }
            }
            if let Err(e) = t.validate() {
                return Err(format!("invariant broken: {e}"));
            }
        }
        Ok(())
    });
}

#[test]
fn radix_arena_reuses_slots_after_eviction() {
    check("radix slot reuse", 20, |g| {
        let mut t = RadixTree::new();
        let n = g.usize_in(16, 64) as u32;
        for i in 0..n {
            // distinct first tokens -> one leaf per insert
            t.insert(&[i * 100, i * 100 + 1, i * 100 + 2]);
        }
        let arena = t.arena_len();
        t.evict_to(0);
        prop_assert!(
            t.free_slots() == n as usize,
            "expected {n} reclaimed slots, got {}",
            t.free_slots()
        );
        for i in 0..n {
            t.insert(&[i * 100 + 7, i * 100 + 8]);
        }
        prop_assert!(
            t.arena_len() == arena,
            "arena grew {} -> {} despite {n} free slots",
            arena,
            t.arena_len()
        );
        t.validate().map_err(|e| format!("post-reuse: {e}"))
    });
}

#[test]
fn radix_eviction_follows_lru_access_order() {
    // leaves must fall in access-time order: untouched sequences go first
    check("radix LRU order", 20, |g| {
        let mut t = RadixTree::new();
        let n = g.usize_in(3, 10) as u32;
        let seqs: Vec<Vec<u32>> = (0..n)
            .map(|i| vec![i * 1000, i * 1000 + 1, i * 1000 + 2, i * 1000 + 3])
            .collect();
        for s in &seqs {
            t.insert(s);
        }
        // touch a random subset; untouched ones are older
        let mut touched = vec![false; n as usize];
        for _ in 0..g.usize_in(1, n as usize) {
            let i = g.usize_in(0, n as usize - 1);
            t.match_prefix(&seqs[i]);
            touched[i] = true;
        }
        let n_untouched = touched.iter().filter(|&&x| !x).count() as u64;
        if n_untouched == 0 {
            return Ok(());
        }
        // evict exactly the untouched mass: every touched leaf must survive
        t.evict_to(t.token_count() - 4 * n_untouched);
        for (i, s) in seqs.iter().enumerate() {
            let hit = t.peek_prefix(s);
            if touched[i] {
                prop_assert!(hit == 4, "touched seq {i} evicted (hit {hit})");
            } else {
                prop_assert!(hit == 0, "untouched seq {i} survived (hit {hit})");
            }
        }
        t.validate().map_err(|e| format!("post-evict: {e}"))
    });
}

#[test]
fn store_capacity_is_always_respected() {
    check("store capacity", 30, |g| {
        let cap_cpu = g.rng.range(50, 400);
        let cap_ssd = g.rng.range(0, 400);
        let mut s = GlobalKvStore::new(StoreConfig {
            cpu_capacity_tokens: cap_cpu,
            ssd_capacity_tokens: cap_ssd,
            ..Default::default()
        });
        for _ in 0..g.usize_in(5, 60) {
            let toks = g.tokens(120, 1000);
            if toks.is_empty() {
                continue;
            }
            s.insert(&toks);
            prop_assert!(
                s.token_count() <= cap_cpu + cap_ssd,
                "store over capacity: {} > {}",
                s.token_count(),
                cap_cpu + cap_ssd
            );
        }
        Ok(())
    });
}

#[test]
fn store_tier_residency_is_conserved_under_churn() {
    // hot + cold must always equal the tree's total token count, the total
    // must respect cpu+ssd capacity, and every lookup's hot/cold split must
    // sum to its hit count — across random interleavings of insert/lookup
    // with small random tier budgets that force demotion and cold eviction.
    check("store tier conservation", 30, |g| {
        let cap_cpu = g.rng.range(40, 200);
        let cap_ssd = g.rng.range(0, 300);
        let mut s = GlobalKvStore::new(StoreConfig {
            cpu_capacity_tokens: cap_cpu,
            ssd_capacity_tokens: cap_ssd,
            ..Default::default()
        });
        let vocab = g.rng.range(2, 10);
        for _ in 0..g.usize_in(10, 80) {
            let toks = g.tokens(90, vocab);
            if toks.is_empty() {
                continue;
            }
            if g.rng.chance(0.5) {
                s.insert(&toks);
            } else {
                let plan = s.lookup(&toks, &LLAMA31_8B, 4e-3);
                prop_assert!(
                    plan.hot_tokens + plan.cold_tokens == plan.hit_tokens,
                    "tier split {} + {} != hit {}",
                    plan.hot_tokens,
                    plan.cold_tokens,
                    plan.hit_tokens
                );
            }
            prop_assert!(
                s.hot_token_count() + s.cold_token_count() == s.token_count(),
                "residency leak: hot {} + cold {} != total {}",
                s.hot_token_count(),
                s.cold_token_count(),
                s.token_count()
            );
            prop_assert!(
                s.token_count() <= cap_cpu + cap_ssd,
                "store over total capacity: {} > {}",
                s.token_count(),
                cap_cpu + cap_ssd
            );
        }
        Ok(())
    });
}

#[test]
fn store_lookup_hits_are_prefixes_of_insertions() {
    check("store hit soundness", 30, |g| {
        let mut s = GlobalKvStore::new(StoreConfig::default());
        let vocab = g.rng.range(2, 8);
        let mut inserted: Vec<Vec<u32>> = Vec::new();
        for _ in 0..g.usize_in(1, 12) {
            let toks = g.tokens(40, vocab);
            if toks.is_empty() {
                continue;
            }
            s.insert(&toks);
            inserted.push(toks);
        }
        for _ in 0..10 {
            let q = g.tokens(50, vocab);
            let plan = s.lookup(&q, &LLAMA31_8B, 4e-3);
            let want = naive_match(&inserted, &q);
            prop_assert!(
                plan.hit_tokens == want,
                "hit {} vs oracle {} for {q:?}",
                plan.hit_tokens,
                want
            );
            prop_assert!(plan.stall >= 0.0, "negative stall");
        }
        Ok(())
    });
}
