//! Real serving path end-to-end: the threaded coordinator drives the PJRT
//! runtime with continuous batching. Requires `make artifacts` and the
//! `pjrt` feature (xla bindings).
#![cfg(feature = "pjrt")]

use banaserve::coordinator::{serve, ServeConfig, ServeRequest};

fn reqs(n: usize, max_new: usize) -> Vec<ServeRequest> {
    (0..n)
        .map(|i| ServeRequest {
            id: i as u64,
            prompt: (0..(4 + i % 12)).map(|t| ((t * 7 + i) % 256) as i32).collect(),
            max_new_tokens: max_new,
        })
        .collect()
}

#[test]
fn serves_all_requests_single_worker() {
    let cfg = ServeConfig {
        n_workers: 1,
        ..Default::default()
    };
    let (responses, stats) = serve(&cfg, reqs(6, 8)).unwrap();
    assert_eq!(responses.len(), 6);
    assert_eq!(stats.completed, 6);
    for r in &responses {
        assert_eq!(r.tokens.len(), 8, "req {} generated {}", r.id, r.tokens.len());
        assert!(r.ttft <= r.e2e);
        assert!(r.tokens.iter().all(|&t| (0..256).contains(&t)));
    }
    assert!(stats.throughput_tok_s > 0.0);
}

#[test]
fn serves_across_two_workers() {
    let cfg = ServeConfig {
        n_workers: 2,
        ..Default::default()
    };
    let (responses, stats) = serve(&cfg, reqs(10, 6)).unwrap();
    assert_eq!(responses.len(), 10);
    assert_eq!(stats.total_generated, 60);
    // both workers should have picked up work on a 10-request run
    let mut workers: Vec<usize> = responses.iter().map(|r| r.worker).collect();
    workers.sort_unstable();
    workers.dedup();
    assert!(!workers.is_empty());
}

#[test]
fn generation_is_deterministic_per_prompt() {
    // greedy decoding through the coordinator must be a pure function of
    // the prompt — independent of batch-mates, worker, or scheduling.
    let cfg = ServeConfig {
        n_workers: 1,
        ..Default::default()
    };
    let prompt: Vec<i32> = vec![3, 10, 17, 24, 31];
    let mk = |id| ServeRequest {
        id,
        prompt: prompt.clone(),
        max_new_tokens: 10,
    };
    // run the same prompt alone...
    let (solo, _) = serve(&cfg, vec![mk(0)]).unwrap();
    // ...and among a full, diverse batch on 2 workers
    let mut batch = reqs(7, 10);
    batch.push(mk(99));
    let cfg2 = ServeConfig {
        n_workers: 2,
        ..Default::default()
    };
    let (mixed, _) = serve(&cfg2, batch).unwrap();
    let solo_tokens = &solo[0].tokens;
    let mixed_tokens = &mixed.iter().find(|r| r.id == 99).unwrap().tokens;
    assert_eq!(solo_tokens, mixed_tokens, "batching changed the output");
}

#[test]
fn oversized_prompt_is_rejected_cleanly() {
    let cfg = ServeConfig {
        n_workers: 1,
        ..Default::default()
    };
    let bad = vec![ServeRequest {
        id: 0,
        prompt: vec![1; 64], // prefill entry is fixed at 32
        max_new_tokens: 4,
    }];
    assert!(serve(&cfg, bad).is_err());
}
