//! Property and identity gates for the scalable-routing layer (ISSUE 7):
//!
//! * **Index exactness** — over randomized load/weight/membership
//!   trajectories, every tournament-tree pick must equal the corresponding
//!   router's linear scan over the eligible subset, for every [`TreeKey`]
//!   and for the indexed Alg 2 rotation.
//! * **Sampler soundness** — p2c candidates are distinct, eligible and
//!   bounded by `k`; fleets with `n <= k` enumerate without consuming any
//!   randomness.
//! * **Default-off byte-identity** — `auto` resolves to the exact scan at
//!   fleet ≤ 64, so fixed-seed Reports are byte-identical to explicit
//!   `scan` on all four engines (the golden-snapshot protection).
//! * **End-to-end identity** — tournament-routed vLLM `LeastLoaded` on a
//!   static fleet reproduces the scan's routing decision-for-decision.

use banaserve::config::{EngineKind, ExperimentConfig, RouteMode};
use banaserve::engines::fleet::{self, Router, TreeKey};
use banaserve::engines::run_experiment;
use banaserve::engines::vllm_sim::{RouterPolicy, VllmEngine};
use banaserve::prop_assert;
use banaserve::sim::{self, Engine};
use banaserve::util::checker::check;
use banaserve::util::json;
use banaserve::workload::{LengthProfile, WorkloadConfig};

/// The Report fields the golden snapshot pins, as a comparable string.
fn fingerprint(cfg: &ExperimentConfig) -> String {
    let out = run_experiment(cfg);
    let r = &out.report;
    json::write(&json::obj(vec![
        ("submitted", json::num(out.submitted as f64)),
        ("n_requests", json::num(r.n_requests as f64)),
        ("dropped", json::num(r.dropped as f64)),
        ("output_tokens", json::num(r.output_tokens as f64)),
        ("input_tokens", json::num(r.input_tokens as f64)),
        ("cached_tokens", json::num(r.cached_tokens as f64)),
        ("makespan", json::num(r.makespan)),
        ("throughput_tok_s", json::num(r.throughput_tok_s)),
        ("ttft_mean", json::num(r.ttft.mean())),
        ("tpot_mean", json::num(r.tpot.mean())),
        ("e2e_mean", json::num(r.e2e.mean())),
        ("queue_mean", json::num(r.queue.mean())),
    ]))
}

#[test]
fn tournament_picks_match_the_exact_scan_for_every_policy() {
    check("tournament == scan", 60, |g| {
        let n = g.usize_in(1, 170);
        let mut book = fleet::LoadBook::with_instances(n);
        book.enable_index(&[
            TreeKey::LeastLoaded,
            TreeKey::LeastQueue,
            TreeKey::MostFreeMem,
            TreeKey::LoadAwareU,
            TreeKey::LoadAwareQ,
        ]);
        let mut elig = vec![true; n];
        let steps = g.usize_in(1, 30);
        for _ in 0..steps {
            // a batch of load syncs + membership flips between picks, the
            // pattern the engines produce (dirty set flushed per pick)
            for _ in 0..g.usize_in(1, 8) {
                let i = g.usize_in(0, n - 1);
                match g.usize_in(0, 3) {
                    0 => book.set_queue(i, g.usize_in(0, 12), g.usize_in(0, 40)),
                    1 => {
                        let e = book.entry_mut(i);
                        e.u = g.f64_in(0.0, 2.0);
                        e.mem_free = g.rng.range(0, 1 << 30);
                        e.running = g.usize_in(0, 16);
                    }
                    2 => {
                        book.entry_mut(i).weight =
                            if g.bool() { 1.0 } else { g.f64_in(0.5, 2.0) };
                    }
                    _ => {
                        elig[i] = !elig[i];
                        book.set_eligible(i, elig[i]);
                    }
                }
            }
            let view: Vec<fleet::InstanceLoad> =
                book.loads().iter().filter(|l| elig[l.idx]).copied().collect();
            let scan_ll = fleet::LeastLoaded.pick(&view).map(|p| view[p].idx);
            let got_ll = book.pick_indexed(TreeKey::LeastLoaded);
            prop_assert!(got_ll == scan_ll, "LeastLoaded: tree {got_ll:?} != scan {scan_ll:?}");
            let scan_lq = fleet::LeastQueue.pick(&view).map(|p| view[p].idx);
            let got_lq = book.pick_indexed(TreeKey::LeastQueue);
            prop_assert!(got_lq == scan_lq, "LeastQueue: tree {got_lq:?} != scan {scan_lq:?}");
            let scan_mf = fleet::MostFreeMem.pick(&view).map(|p| view[p].idx);
            let got_mf = book.pick_indexed(TreeKey::MostFreeMem);
            prop_assert!(got_mf == scan_mf, "MostFreeMem: tree {got_mf:?} != scan {scan_mf:?}");
            let delta_l = g.f64_in(0.5, 2.0);
            let rr = g.usize_in(0, 999);
            let scan_la = fleet::pick_load_aware(&view, delta_l, rr).map(|p| view[p].idx);
            let got_la = book.pick_indexed_load_aware(delta_l, rr);
            prop_assert!(
                got_la == scan_la,
                "Alg 2 (delta_l {delta_l:.3}, rr {rr}): tree {got_la:?} != scan {scan_la:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn sampled_candidates_are_distinct_eligible_and_bounded() {
    check("p2c sampler", 50, |g| {
        let n = g.usize_in(0, 50);
        let k = g.usize_in(1, 6);
        let mask: Vec<bool> = (0..n).map(|_| g.bool()).collect();
        let mut s = fleet::RouteSampler::new(g.rng.next_u64());
        let cands: Vec<usize> = s.sample(n, k, |i| mask[i]).to_vec();
        let mut dedup = cands.clone();
        dedup.sort_unstable();
        dedup.dedup();
        prop_assert!(dedup.len() == cands.len(), "duplicate candidates: {cands:?}");
        prop_assert!(
            cands.iter().all(|&i| i < n && mask[i]),
            "out-of-range or ineligible candidate: {cands:?}"
        );
        if n > k {
            prop_assert!(cands.len() <= k, "more than k candidates: {cands:?}");
        } else {
            let want: Vec<usize> = (0..n).filter(|&i| mask[i]).collect();
            prop_assert!(
                cands == want,
                "small fleet must enumerate the eligible set: {cands:?} != {want:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn small_fleet_sampling_consumes_no_randomness() {
    // n <= k enumerates without drawing, so a sampler that served a small
    // fleet stays stream-identical to a fresh one — the zero-perturbation
    // half of the byte-identity guarantee
    let mut a = fleet::RouteSampler::new(7);
    let mut b = fleet::RouteSampler::new(7);
    let _ = a.sample(4, 8, |_| true).to_vec();
    let x = a.sample(100, 2, |_| true).to_vec();
    let y = b.sample(100, 2, |_| true).to_vec();
    assert_eq!(x, y, "n <= k sampling must not advance the PRNG");
}

#[test]
fn auto_mode_at_small_fleets_is_byte_identical_to_explicit_scan() {
    for kind in [
        EngineKind::HfStatic,
        EngineKind::Vllm,
        EngineKind::DistServe,
        EngineKind::BanaServe,
    ] {
        let mk = |mode: RouteMode| {
            let mut c = ExperimentConfig::default_for(kind, "llama-13b", 6.0, 1234);
            c.workload = WorkloadConfig::poisson(LengthProfile::AlpacaShort, 6.0, 20.0, 1234);
            c.warmup = 0.0;
            c.routing.mode = mode;
            c
        };
        let auto = mk(RouteMode::Auto);
        assert_eq!(
            auto.routing.resolve(auto.n_devices),
            RouteMode::Scan,
            "{kind:?}: auto must resolve to the exact scan at fleet <= 64"
        );
        assert_eq!(
            fingerprint(&auto),
            fingerprint(&mk(RouteMode::Scan)),
            "{kind:?}: default routing at fleet <= 64 must stay byte-identical to scan"
        );
    }
}

#[test]
fn tournament_routed_vllm_least_loaded_matches_scan_end_to_end() {
    // on a static no-fault fleet every instance is always an eligible,
    // unfrozen winner candidate, so the indexed pick must reproduce the
    // scan's routing decision-for-decision — not just statistically
    let run = |mode: RouteMode| {
        let mut c = ExperimentConfig::default_for(EngineKind::Vllm, "llama-13b", 10.0, 77);
        c.n_devices = 6;
        c.warmup = 0.0;
        c.workload = WorkloadConfig::poisson(LengthProfile::AlpacaShort, 10.0, 20.0, 77);
        c.routing.mode = mode;
        let reqs = c.workload.generate();
        let mut e = VllmEngine::with_policy(&c, RouterPolicy::LeastLoaded, true);
        sim::run(&mut e, reqs, 1e6);
        let recs: Vec<(u64, f64, f64)> = e
            .collector()
            .records
            .iter()
            .map(|r| (r.id, r.ttft(), r.e2e()))
            .collect();
        (e.routed_counts.clone(), recs)
    };
    let (rc_scan, rec_scan) = run(RouteMode::Scan);
    let (rc_tree, rec_tree) = run(RouteMode::Tournament);
    assert_eq!(rc_scan, rc_tree, "tournament must reproduce the scan's routed counts");
    assert_eq!(rec_scan.len(), rec_tree.len());
    for (a, b) in rec_scan.iter().zip(rec_tree.iter()) {
        assert_eq!(a.0, b.0, "request order diverged");
        assert!(
            (a.1 - b.1).abs() < 1e-12 && (a.2 - b.2).abs() < 1e-12,
            "latency diverged for req {}: scan ({}, {}) vs tournament ({}, {})",
            a.0, a.1, a.2, b.1, b.2
        );
    }
}

#[test]
fn p2c_and_tournament_runs_conserve_and_replay_deterministically() {
    for kind in [
        EngineKind::HfStatic,
        EngineKind::Vllm,
        EngineKind::DistServe,
        EngineKind::BanaServe,
    ] {
        for mode in [RouteMode::P2c, RouteMode::Tournament] {
            let mk = || {
                let mut c = ExperimentConfig::default_for(kind, "llama-13b", 6.0, 9);
                c.n_devices = 5;
                c.n_prefill = 2;
                c.warmup = 0.0;
                c.workload = WorkloadConfig::poisson(LengthProfile::AlpacaShort, 6.0, 12.0, 9);
                c.routing.mode = mode;
                c
            };
            let out = run_experiment(&mk());
            assert_eq!(
                out.submitted,
                out.report.n_requests + out.report.dropped,
                "{kind:?} {mode:?}: requests not conserved"
            );
            assert_eq!(
                fingerprint(&mk()),
                fingerprint(&mk()),
                "{kind:?} {mode:?}: sampled routing must replay deterministically"
            );
        }
    }
}
