//! Property tests over the scheduling policies and the full engines:
//! request conservation, KV accounting, latency sanity, routing and
//! migration invariants — randomized over workloads, cluster shapes and
//! engine knobs.

use banaserve::config::{EngineKind, ExperimentConfig};
use banaserve::engines::banaserve::migration::{self, DeviceLoad, Policy};
use banaserve::engines::banaserve::scheduler::{self, InstanceLoad};
use banaserve::engines::banaserve::BanaEngine;
use banaserve::engines::distserve_sim::DistServeEngine;
use banaserve::engines::fleet::{self, Router};
use banaserve::engines::hft::HftEngine;
use banaserve::engines::vllm_sim::{RouterPolicy, VllmEngine};
use banaserve::prop_assert;
use banaserve::sim::{self, Engine};
use banaserve::util::checker::{check, Gen};
use banaserve::workload::{ArrivalProcess, LengthProfile, WorkloadConfig};

fn random_cfg(g: &mut Gen, engine: EngineKind) -> ExperimentConfig {
    let mut c = ExperimentConfig::default_for(engine, "llama-13b", 1.0, g.rng.next_u64());
    c.n_devices = g.usize_in(2, 5);
    c.n_prefill = g.usize_in(1, c.n_devices - 1);
    let profile = if g.bool() {
        LengthProfile::AlpacaShort
    } else {
        LengthProfile::LongBench
    };
    let rps = g.f64_in(0.5, 8.0);
    c.workload = WorkloadConfig::poisson(profile, rps, g.f64_in(3.0, 12.0), g.rng.next_u64());
    if g.bool() {
        c.workload.arrivals = ArrivalProcess::Bursty {
            rps,
            burst_factor: g.f64_in(2.0, 6.0),
            burst_secs: 2.0,
            period_secs: 8.0,
        };
    }
    c.workload.prefix.share_prob = g.f64_in(0.0, 0.95);
    c.warmup = 0.0;
    c.bana.layer_migration = g.bool();
    c.bana.attention_migration = g.bool();
    c.bana.global_store = g.bool();
    c.bana.control_period = g.f64_in(0.5, 3.0);
    c
}

/// The cross-engine invariant bundle every run must satisfy.
fn check_invariants(
    label: &str,
    res: &sim::RunResult,
    engine: &mut dyn Engine,
    device_kv: &[u64],
) -> Result<(), String> {
    sim::check_conservation(res, engine).map_err(|e| format!("{label}: {e}"))?;
    let col = engine.collector();
    for r in &col.records {
        if r.ttft() < 0.0 || r.e2e() < r.ttft() - 1e-9 || r.queue_delay() < -1e-9 {
            return Err(format!(
                "{label}: latency ordering violated for req {}: ttft={} e2e={}",
                r.id,
                r.ttft(),
                r.e2e()
            ));
        }
        if r.cached_tokens > r.prompt_len {
            return Err(format!("{label}: cached > prompt for req {}", r.id));
        }
    }
    if engine.inflight() == 0 {
        for (i, &kv) in device_kv.iter().enumerate() {
            if kv != 0 {
                return Err(format!("{label}: device {i} leaked {kv} KV bytes"));
            }
        }
    }
    Ok(())
}

#[test]
fn all_engines_satisfy_invariants_on_random_workloads() {
    check("engine invariants", 24, |g| {
        let kind = *g.pick(&[
            EngineKind::HfStatic,
            EngineKind::Vllm,
            EngineKind::DistServe,
            EngineKind::BanaServe,
        ]);
        let cfg = random_cfg(g, kind);
        let reqs = cfg.workload.generate();
        match kind {
            EngineKind::HfStatic => {
                let mut e = HftEngine::new(&cfg);
                let res = sim::run(&mut e, reqs, 1e5);
                let kv: Vec<u64> = e.devices.iter().map(|d| d.kv_bytes).collect();
                check_invariants("hft", &res, &mut e, &kv)
            }
            EngineKind::Vllm => {
                let mut e = VllmEngine::new(&cfg);
                let res = sim::run(&mut e, reqs, 1e5);
                let kv: Vec<u64> = e.devices.iter().map(|d| d.kv_bytes).collect();
                check_invariants("vllm", &res, &mut e, &kv)
            }
            EngineKind::DistServe => {
                let mut e = DistServeEngine::new(&cfg);
                let res = sim::run(&mut e, reqs, 1e5);
                let kv: Vec<u64> = e.devices.iter().map(|d| d.kv_bytes).collect();
                check_invariants("distserve", &res, &mut e, &kv)
            }
            EngineKind::BanaServe => {
                let mut e = BanaEngine::new(&cfg);
                let res = sim::run(&mut e, reqs, 1e5);
                let kv: Vec<u64> = e.devices.iter().map(|d| d.kv_bytes).collect();
                check_invariants("banaserve", &res, &mut e, &kv)
            }
        }
    });
}

#[test]
fn banaserve_completes_everything_it_admits() {
    check("banaserve drains", 12, |g| {
        let cfg = random_cfg(g, EngineKind::BanaServe);
        let reqs = cfg.workload.generate();
        let n = reqs.len() as u64;
        let mut e = BanaEngine::new(&cfg);
        let res = sim::run(&mut e, reqs, 1e5);
        let done = e.collector().completed();
        let dropped = e.collector().dropped;
        prop_assert!(
            done + dropped == n && e.inflight() == 0,
            "stranded work: n={n} done={done} dropped={dropped} inflight={} end={}",
            e.inflight(),
            res.end_time
        );
        Ok(())
    });
}

#[test]
fn scheduler_pick_is_always_a_candidate_and_respects_order() {
    check("alg2 pick", 60, |g| {
        let n = g.usize_in(1, 12);
        let loads: Vec<InstanceLoad> = (0..n)
            .map(|idx| InstanceLoad {
                idx,
                u: g.f64_in(0.0, 2.0),
                queue_len: g.usize_in(0, 30),
                pending: 0.0,
            })
            .collect();
        let delta_l = g.f64_in(0.2, 2.0);
        let Some(p) = scheduler::pick(&loads, delta_l) else {
            return Err("pick returned None for non-empty candidates".into());
        };
        prop_assert!(p < loads.len(), "pick out of range");
        let chosen = loads[p];
        if chosen.u < delta_l {
            // below threshold: must be a minimal-load choice
            let min_u = loads.iter().map(|l| l.u).fold(f64::INFINITY, f64::min);
            prop_assert!(
                chosen.u <= min_u + 1e-12,
                "picked u={} but min is {}",
                chosen.u,
                min_u
            );
        } else {
            // fallback: must be a minimal-queue choice
            let min_q = loads.iter().map(|l| l.queue_len).min().unwrap();
            prop_assert!(
                chosen.queue_len == min_q,
                "fallback picked queue {} but min is {}",
                chosen.queue_len,
                min_q
            );
        }
        Ok(())
    });
}

#[test]
fn burst_dispatch_never_exceeds_proportional_share_plus_one() {
    check("alg2 burst fairness", 30, |g| {
        let n = g.usize_in(2, 8);
        let mut loads: Vec<InstanceLoad> = (0..n)
            .map(|idx| InstanceLoad {
                idx,
                u: 0.3,
                queue_len: 0,
                pending: 0.0,
            })
            .collect();
        let k = g.usize_in(n, 4 * n);
        let picks = scheduler::dispatch_burst(&mut loads, k, 1.8, 0.1);
        let mut counts = vec![0usize; n];
        for p in picks {
            counts[p] += 1;
        }
        let fair = k.div_ceil(n);
        for (i, c) in counts.iter().enumerate() {
            prop_assert!(
                *c <= fair + 1,
                "instance {i} got {c} of {k} (fair {fair})"
            );
        }
        Ok(())
    });
}

#[test]
fn migration_plan_is_feasible_and_terminates() {
    check("alg1 plan feasibility", 50, |g| {
        let n = g.usize_in(2, 8);
        let loads: Vec<DeviceLoad> = (0..n)
            .map(|idx| {
                let mem = g.f64_in(0.1, 1.0);
                let extra = g.f64_in(0.0, 1.0);
                let share = g.f64_in(0.0, 1.0);
                DeviceLoad {
                    idx,
                    u: mem + extra,
                    mem_frac: mem,
                    share_prefill: share,
                    free_bytes: g.rng.range(0, 20_000_000_000),
                    busy_prefill: extra * share,
                    busy_decode: extra * (1.0 - share),
                }
            })
            .collect();
        let pol = Policy {
            delta: g.f64_in(0.1, 0.8),
            rho: g.f64_in(0.2, 3.0),
            period: 2.0,
            layer_step: 0.25,
            enable_layer: g.bool(),
            enable_attention: g.bool(),
        };
        let actions = migration::plan(&loads, &pol, g.f64_in(0.01, 1.0), g.f64_in(0.001, 0.1));
        prop_assert!(actions.len() <= n, "more actions than devices");
        for a in &actions {
            match a {
                migration::Action::Layer {
                    from,
                    to,
                    delta_share,
                    ..
                } => {
                    prop_assert!(*from < n && *to < n, "layer idx out of range");
                    prop_assert!(pol.enable_layer, "layer action while disabled");
                    prop_assert!(
                        *delta_share > 0.0 && *delta_share <= 1.0,
                        "bad delta_share {delta_share}"
                    );
                }
                migration::Action::Attention { from, to, kv_frac } => {
                    prop_assert!(*from < n && *to < n && from != to, "attention idx");
                    prop_assert!(pol.enable_attention, "attention action while disabled");
                    prop_assert!(
                        *kv_frac > 0.0 && *kv_frac <= 0.5,
                        "bad kv_frac {kv_frac}"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn fleet_routers_never_starve_an_instance() {
    // LeastLoaded and RoundRobin: when each pick adds load to the chosen
    // instance (the engines' feedback loop), every instance must receive
    // work within a bounded number of arrivals.
    check("router starvation-freedom", 40, |g| {
        let n = g.usize_in(2, 10);
        let k = 7 * n;
        for mode in 0..2usize {
            let mut rr = fleet::RoundRobin::default();
            let mut ll = fleet::LeastLoaded;
            let mut loads: Vec<fleet::InstanceLoad> = (0..n)
                .map(|i| {
                    let mut l = fleet::InstanceLoad::at(i);
                    l.load_seqs = g.usize_in(0, 5);
                    l.queue_len = l.load_seqs;
                    l
                })
                .collect();
            let mut counts = vec![0usize; n];
            for _ in 0..k {
                let pos = if mode == 0 {
                    rr.pick(&loads)
                } else {
                    ll.pick(&loads)
                }
                .expect("non-empty");
                counts[pos] += 1;
                loads[pos].load_seqs += 1;
                loads[pos].queue_len += 1;
            }
            prop_assert!(
                counts.iter().all(|&c| c > 0),
                "mode {mode}: starved instance after {k} picks: {counts:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn load_book_always_matches_rebuilt_snapshots() {
    // the maintained LoadBook (incremental set_queue syncs at admit/step/
    // finish transitions) must be indistinguishable from a snapshot rebuilt
    // from scratch — for the full slice, for filtered views, and for every
    // router's pick over either
    check("loadbook vs rebuilt snapshot", 40, |g| {
        let n = g.usize_in(1, 12);
        let mut book = fleet::LoadBook::with_instances(n);
        // model state: per-instance (waiting, running) counters
        let mut model: Vec<(usize, usize)> = vec![(0, 0); n];
        let steps = g.usize_in(1, 80);
        for _ in 0..steps {
            let i = g.usize_in(0, n - 1);
            match g.usize_in(0, 3) {
                0 => model[i].0 += 1, // admit: waiting += 1
                1 => {
                    // step start: waiting -> running
                    if model[i].0 > 0 {
                        model[i].0 -= 1;
                        model[i].1 += 1;
                    }
                }
                2 => model[i].1 = model[i].1.saturating_sub(1), // finish
                _ => {}                                         // idle event
            }
            book.set_queue(i, model[i].0, model[i].0 + model[i].1);

            let rebuilt: Vec<fleet::InstanceLoad> = (0..n)
                .map(|j| {
                    let mut l = fleet::InstanceLoad::at(j);
                    l.queue_len = model[j].0;
                    l.load_seqs = model[j].0 + model[j].1;
                    l
                })
                .collect();
            prop_assert!(
                book.loads() == &rebuilt[..],
                "maintained slice diverged from rebuild: {:?} vs {rebuilt:?}",
                book.loads()
            );
            let keep = |l: &fleet::InstanceLoad| l.queue_len > 0;
            let want: Vec<fleet::InstanceLoad> =
                rebuilt.iter().copied().filter(keep).collect();
            prop_assert!(
                book.filtered(keep) == &want[..],
                "filtered view diverged from filtered rebuild"
            );
            let a = fleet::LeastLoaded.pick(book.loads());
            let b = fleet::LeastLoaded.pick(&rebuilt);
            prop_assert!(a == b, "LeastLoaded diverged: {a:?} vs {b:?}");
            let a = fleet::LeastQueue.pick(book.loads());
            let b = fleet::LeastQueue.pick(&rebuilt);
            prop_assert!(a == b, "LeastQueue diverged: {a:?} vs {b:?}");
        }
        Ok(())
    });
}

// --- router heterogeneity -------------------------------------------------
//
// Pre-weight reference implementations of the fleet policies, kept here
// verbatim from PR 2/3: with every weight at 1.0 the weighted policies
// must reproduce these picks byte-identically over any event stream.

fn ref_least_loaded(loads: &[fleet::InstanceLoad]) -> Option<usize> {
    loads
        .iter()
        .enumerate()
        .min_by_key(|(_, l)| (l.load_seqs, l.queue_len, l.idx))
        .map(|(p, _)| p)
}

fn ref_least_queue(loads: &[fleet::InstanceLoad]) -> Option<usize> {
    loads
        .iter()
        .enumerate()
        .min_by_key(|(_, l)| (l.queue_len, l.load_seqs, l.idx))
        .map(|(p, _)| p)
}

fn ref_most_free_mem(loads: &[fleet::InstanceLoad]) -> Option<usize> {
    loads
        .iter()
        .enumerate()
        .max_by_key(|(_, l)| (l.mem_free, std::cmp::Reverse(l.running)))
        .map(|(p, _)| p)
}

fn ref_cache_aware(loads: &[fleet::InstanceLoad], w_cache: f64, w_load: f64) -> Option<usize> {
    let max_load = loads.iter().map(|l| l.load_seqs).max().unwrap_or(0).max(1) as f64;
    let score = |l: &fleet::InstanceLoad| {
        w_cache * l.cache_hit - w_load * (l.load_seqs as f64 / max_load)
    };
    loads
        .iter()
        .enumerate()
        .max_by(|(_, a), (_, b)| score(a).total_cmp(&score(b)))
        .map(|(p, _)| p)
}

#[test]
fn equal_weight_policies_match_preweight_references_on_event_streams() {
    // fixed-seed event-stream comparison: random mutations between picks,
    // every policy compared against its pre-weight reference at each step
    check("weight-1 router parity", 40, |g| {
        let n = g.usize_in(1, 10);
        let mut loads: Vec<fleet::InstanceLoad> = (0..n)
            .map(|i| {
                let mut l = fleet::InstanceLoad::at(i); // weight == 1.0
                l.load_seqs = g.usize_in(0, 8);
                l.queue_len = g.usize_in(0, 8);
                l.running = g.usize_in(0, 8);
                l.mem_free = g.rng.range(0, 1_000_000);
                l.cache_hit = g.f64_in(0.0, 1.0);
                l
            })
            .collect();
        let (w_cache, w_load) = (g.f64_in(0.1, 2.0), g.f64_in(0.1, 2.0));
        let mut ca = fleet::CacheAware { w_cache, w_load };
        let steps = g.usize_in(1, 60);
        for _ in 0..steps {
            // event: one instance's counters move (admit/step/finish)
            let i = g.usize_in(0, n - 1);
            match g.usize_in(0, 4) {
                0 => loads[i].load_seqs += 1,
                1 => loads[i].load_seqs = loads[i].load_seqs.saturating_sub(1),
                2 => loads[i].queue_len += 1,
                3 => loads[i].queue_len = loads[i].queue_len.saturating_sub(1),
                _ => loads[i].mem_free = g.rng.range(0, 1_000_000),
            }
            prop_assert!(
                fleet::LeastLoaded.pick(&loads) == ref_least_loaded(&loads),
                "LeastLoaded diverged from pre-weight reference: {loads:?}"
            );
            prop_assert!(
                fleet::LeastQueue.pick(&loads) == ref_least_queue(&loads),
                "LeastQueue diverged from pre-weight reference: {loads:?}"
            );
            prop_assert!(
                fleet::MostFreeMem.pick(&loads) == ref_most_free_mem(&loads),
                "MostFreeMem diverged from pre-weight reference: {loads:?}"
            );
            prop_assert!(
                ca.pick(&loads) == ref_cache_aware(&loads, w_cache, w_load),
                "CacheAware diverged from pre-weight reference: {loads:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn weighted_least_loaded_long_run_ratio_tracks_capacity() {
    // a 2x-weight device must absorb ~2x the assignments under the
    // engines' feedback loop (each pick adds one resident to the target)
    check("2x-weight assignment ratio", 20, |g| {
        let heavy = g.usize_in(0, 1); // which of the two is the 2x device
        let mut loads: Vec<fleet::InstanceLoad> = (0..2)
            .map(|i| {
                let mut l = fleet::InstanceLoad::at(i);
                l.weight = if i == heavy { 2.0 } else { 1.0 };
                l
            })
            .collect();
        let k = 300;
        let mut counts = [0usize; 2];
        for _ in 0..k {
            let pos = fleet::LeastLoaded.pick(&loads).unwrap();
            counts[pos] += 1;
            loads[pos].load_seqs += 1;
            loads[pos].queue_len += 1;
        }
        let ratio = counts[heavy] as f64 / counts[1 - heavy].max(1) as f64;
        prop_assert!(
            (1.7..=2.3).contains(&ratio),
            "assignment ratio {ratio:.2} should track the 2x weight \
             (counts {counts:?}, heavy={heavy})"
        );
        Ok(())
    });
}

#[test]
fn fleet_load_aware_pick_matches_scheduler_alg2() {
    // fleet::pick_load_aware is an allocation-free port of
    // scheduler::pick_rotating; they must agree on every input
    check("alg2 parity", 80, |g| {
        let n = g.usize_in(1, 12);
        let fl: Vec<fleet::InstanceLoad> = (0..n)
            .map(|idx| {
                let mut l = fleet::InstanceLoad::at(idx);
                l.u = g.f64_in(0.0, 2.0);
                l.queue_len = g.usize_in(0, 20);
                l
            })
            .collect();
        let sc: Vec<InstanceLoad> = fl
            .iter()
            .map(|l| InstanceLoad {
                idx: l.idx,
                u: l.u,
                queue_len: l.queue_len,
                pending: 0.0,
            })
            .collect();
        let delta_l = g.f64_in(0.2, 2.0);
        let rr = g.usize_in(0, 7);
        let a = fleet::pick_load_aware(&fl, delta_l, rr);
        let b = scheduler::pick_rotating(&sc, delta_l, rr);
        prop_assert!(a == b, "diverged: fleet {a:?} vs scheduler {b:?}");
        Ok(())
    });
}

#[test]
fn cache_aware_router_skews_more_than_least_loaded_fig2a() {
    // Fig 2a direction: on a shared-prefix workload the cache-aware policy
    // must spread routed counts MORE unevenly than least-loaded.
    let mk = || {
        let mut c = ExperimentConfig::default_for(EngineKind::Vllm, "llama-13b", 12.0, 3);
        c.workload =
            WorkloadConfig::poisson(LengthProfile::AlpacaShort, 12.0, 20.0, 3);
        c.warmup = 0.0;
        c.workload.prefix.share_prob = 0.95;
        c.workload.prefix.n_templates = 3;
        c.workload.prefix.zipf_s = 1.5;
        c.workload.prefix.shared_frac = (0.8, 0.95);
        c
    };
    let spread = |counts: &[u64]| {
        let max = *counts.iter().max().unwrap() as f64;
        let min = *counts.iter().min().unwrap() as f64;
        max / min.max(1.0)
    };
    let c = mk();
    let reqs = c.workload.generate();
    let mut cache = VllmEngine::new(&c);
    sim::run(&mut cache, reqs.clone(), 1e6);
    let mut ll = VllmEngine::with_policy(&c, RouterPolicy::LeastLoaded, true);
    sim::run(&mut ll, reqs, 1e6);
    let (s_cache, s_ll) = (spread(&cache.routed_counts), spread(&ll.routed_counts));
    assert!(
        s_cache > s_ll,
        "cache-aware spread {s_cache:.2} must exceed least-loaded {s_ll:.2}"
    );
}

#[test]
fn trace_replay_reproduces_identical_reports() {
    // running the same generated trace twice must give bit-identical
    // metrics — the determinism the 5-seed methodology depends on.
    check("determinism", 8, |g| {
        let cfg = random_cfg(g, EngineKind::BanaServe);
        let reqs = cfg.workload.generate();
        let mut e1 = BanaEngine::new(&cfg);
        let r1 = sim::run(&mut e1, reqs.clone(), 1e5);
        let mut e2 = BanaEngine::new(&cfg);
        let r2 = sim::run(&mut e2, reqs, 1e5);
        prop_assert!(
            (r1.end_time - r2.end_time).abs() < 1e-9
                && r1.events_processed == r2.events_processed,
            "nondeterministic run: {} vs {} events {} vs {}",
            r1.end_time,
            r2.end_time,
            r1.events_processed,
            r2.events_processed
        );
        let rep1 = e1.collector().report(r1.end_time);
        let rep2 = e2.collector().report(r2.end_time);
        prop_assert!(
            (rep1.throughput_tok_s - rep2.throughput_tok_s).abs() < 1e-9,
            "throughput differs"
        );
        Ok(())
    });
}
