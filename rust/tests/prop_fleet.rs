//! Property suite for the elastic-fleet autoscaler decision fn
//! (`fleet::Autoscaler::decide`), randomized over configs, load windows
//! and SLO observations:
//!
//! * the cooldown is never violated (no two non-Hold decisions closer
//!   than `cooldown` seconds),
//! * the fleet size stays within `[min_devices, max_devices]` across any
//!   decision trajectory,
//! * a drain never picks the last Active device (nor a non-drainable one),
//! * with no SLO targets set, the decision degrades to the PR 2
//!   busy-fraction util mode bit-identically, regardless of what P99
//!   observations are passed in.
//!
//! Plus the PR 6 fault-layer properties:
//!
//! * no Router policy ever picks a Draining/Released/Failed device when
//!   fed the engines' `LoadBook::filtered(is_active)` view, across
//!   randomized fail/recover/drain/release trajectories,
//! * the seeded `FaultPlan` is a pure function of `(cfg, seed, devices,
//!   horizon)` — same inputs give an identical schedule, a different
//!   seed gives a different one.
//!
//! Plus the PR 8 transfer-plane properties:
//!
//! * under aggressive link chaos (partitions, degradations, device and
//!   store crashes) every engine's transfer-transaction table drains back
//!   to zero by the end of the run and request conservation still holds,
//! * BanaServe's abort/rollback paths leave no residue: no device retains
//!   KV bytes after the drain, and `pinsts[i].share` never diverges from
//!   `share_prefill[i]` (an aborted layer migration must undo its parked
//!   share delta exactly).
//!
//! Run with a fixed seed via `BANASERVE_PROP_SEED` (the CI property-suite
//! step pins one for reproducibility).

use banaserve::cluster::{
    self, gpu_by_name, Device, DeviceState, Role,
};
use banaserve::config::{AutoscaleConfig, EngineKind, ExperimentConfig, FaultConfig};
use banaserve::engines::{banaserve as bana, distserve_sim, hft, vllm_sim};
use banaserve::engines::fleet::{
    pick_load_aware, Autoscaler, CacheAware, FleetLoad, LeastLoaded, LeastQueue, LoadBook,
    MostFreeMem, Router, RoundRobin, ScaleDecision, SloView,
};
use banaserve::fault::FaultPlan;
use banaserve::prop_assert;
use banaserve::sim;
use banaserve::util::checker::{check, Gen};
use banaserve::workload::{LengthProfile, WorkloadConfig};

fn random_cfg(g: &mut Gen, slo: bool) -> AutoscaleConfig {
    let mut c = AutoscaleConfig::default();
    c.enabled = true;
    c.min_devices = g.usize_in(1, 3);
    c.max_devices = g.usize_in(c.min_devices + 1, c.min_devices + 6);
    c.scale_out_util = g.f64_in(0.5, 0.95);
    c.scale_in_util = g.f64_in(0.05, 0.45);
    c.cooldown = g.f64_in(0.5, 8.0);
    c.window = g.f64_in(0.5, 4.0);
    if slo {
        if g.bool() {
            c.ttft_slo_ms = g.f64_in(100.0, 5000.0);
        }
        if g.bool() || c.ttft_slo_ms == 0.0 {
            c.tpot_slo_ms = g.f64_in(10.0, 200.0);
        }
        c.slo_headroom = g.f64_in(0.5, 1.2);
    }
    c
}

fn random_loads(g: &mut Gen, n: usize) -> Vec<FleetLoad> {
    (0..n)
        .map(|idx| FleetLoad {
            idx,
            busy: g.f64_in(0.0, 1.0),
            queued: g.usize_in(0, 12),
            resident: g.usize_in(0, 24),
            drainable: g.bool(),
            // a small mixed catalog: costs tie often enough to exercise
            // the load tie-breaks under the cost-greedy comparator
            cost: *g.pick(&[1.0, 1.0, 1.5, 2.0]),
        })
        .collect()
}

fn random_view(g: &mut Gen) -> SloView {
    SloView {
        p99_ttft: if g.bool() {
            Some(g.f64_in(0.0, 10.0))
        } else {
            None
        },
        p99_tpot: if g.bool() {
            Some(g.f64_in(0.0, 0.5))
        } else {
            None
        },
    }
}

#[test]
fn cooldown_is_never_violated() {
    check("autoscaler cooldown", 40, |g| {
        let cfg = random_cfg(g, g.bool());
        let mut a = Autoscaler::new(cfg);
        let mut now = 0.0;
        let mut last_action: Option<f64> = None;
        for _ in 0..120 {
            let n = g.usize_in(1, cfg.max_devices);
            let loads = random_loads(g, n);
            let view = random_view(g);
            let d = a.decide(now, &loads, g.usize_in(0, 10), view);
            if d != ScaleDecision::Hold {
                if let Some(t) = last_action {
                    prop_assert!(
                        now >= t + cfg.cooldown - 1e-9,
                        "decision at {now} only {}s after the one at {t} \
                         (cooldown {})",
                        now - t,
                        cfg.cooldown
                    );
                }
                last_action = Some(now);
            }
            now += g.f64_in(0.0, cfg.cooldown * 0.9);
        }
        Ok(())
    });
}

#[test]
fn fleet_size_stays_within_bounds_across_any_trajectory() {
    // drive a synthetic fleet purely from the decisions: Out appends an
    // active device, In{v} eventually releases v — the autoscaler must
    // never push the count outside [min, max]
    check("autoscaler bounds", 40, |g| {
        let cfg = random_cfg(g, g.bool());
        let mut a = Autoscaler::new(cfg);
        let mut n = g.usize_in(cfg.min_devices.max(1), cfg.max_devices);
        let mut now = 0.0;
        for _ in 0..150 {
            let loads = random_loads(g, n);
            let view = random_view(g);
            match a.decide(now, &loads, g.usize_in(0, 8), view) {
                ScaleDecision::Out => {
                    prop_assert!(
                        n < cfg.max_devices,
                        "scale-out at max fleet size {n} (max {})",
                        cfg.max_devices
                    );
                    n += 1;
                }
                ScaleDecision::In { victim } => {
                    prop_assert!(
                        n > cfg.min_devices,
                        "drain at min fleet size {n} (min {})",
                        cfg.min_devices
                    );
                    prop_assert!(victim < n, "victim {victim} out of range");
                    n -= 1;
                }
                ScaleDecision::Hold => {}
            }
            prop_assert!(
                n >= cfg.min_devices.max(1) && n <= cfg.max_devices,
                "fleet size {n} escaped [{}, {}]",
                cfg.min_devices,
                cfg.max_devices
            );
            now += g.f64_in(0.0, 2.0 * cfg.cooldown);
        }
        Ok(())
    });
}

#[test]
fn drain_never_picks_the_last_active_device_or_a_non_drainable_one() {
    check("autoscaler drain safety", 60, |g| {
        let mut cfg = random_cfg(g, g.bool());
        // min_devices 0 is the adversarial case: only the n > 1 guard and
        // the drainable flags protect the last device
        cfg.min_devices = g.usize_in(0, 2);
        let mut a = Autoscaler::new(cfg);
        let mut now = 0.0;
        for _ in 0..120 {
            let n = g.usize_in(1, cfg.max_devices.max(2));
            let loads = random_loads(g, n);
            let view = random_view(g);
            if let ScaleDecision::In { victim } = a.decide(now, &loads, 0, view) {
                prop_assert!(n > 1, "drained the last active device");
                let l = loads.iter().find(|l| l.idx == victim);
                prop_assert!(
                    l.map(|l| l.drainable).unwrap_or(false),
                    "victim {victim} is not a drainable active device"
                );
            }
            now += g.f64_in(0.0, 2.0 * cfg.cooldown);
        }
        Ok(())
    });
}

/// The PR 2 busy-fraction *thresholds*, reproduced verbatim as the
/// reference the SLO-mode code path must degrade to when no targets are
/// set. The drain victim comparator is the current cost-greedy one (max
/// cost, then least loaded) — at uniform cost it reduces to the PR 2
/// least-loaded order exactly, which `drain_is_cost_greedy_with_mixed_
/// specs` and the cost-greedy property below pin from both sides.
fn util_reference(
    cfg: &AutoscaleConfig,
    cooldown_until: &mut f64,
    now: f64,
    active: &[FleetLoad],
    global_backlog: usize,
) -> ScaleDecision {
    if !cfg.enabled || active.is_empty() || now < *cooldown_until {
        return ScaleDecision::Hold;
    }
    let n = active.len();
    let mean_busy = active.iter().map(|l| l.busy).sum::<f64>() / n as f64;
    let queued: usize = active.iter().map(|l| l.queued).sum::<usize>() + global_backlog;
    if n < cfg.max_devices && (mean_busy > cfg.scale_out_util || queued > 4 * n) {
        *cooldown_until = now + cfg.cooldown;
        return ScaleDecision::Out;
    }
    if n > cfg.min_devices && n > 1 && mean_busy < cfg.scale_in_util && queued == 0 {
        let victim = active
            .iter()
            .filter(|l| l.drainable)
            .min_by(|a, b| {
                b.cost
                    .total_cmp(&a.cost)
                    .then(a.busy.total_cmp(&b.busy))
                    .then(a.resident.cmp(&b.resident))
                    .then(a.idx.cmp(&b.idx))
            })
            .map(|l| l.idx);
        if let Some(victim) = victim {
            *cooldown_until = now + cfg.cooldown;
            return ScaleDecision::In { victim };
        }
    }
    ScaleDecision::Hold
}

#[test]
fn drain_victim_is_cost_greedy_then_least_loaded() {
    // whenever the autoscaler decides to drain, the victim must be a
    // most-expensive drainable device, and among those the least busy
    // (then fewest-resident, then lowest-idx) one
    check("autoscaler cost-greedy drain", 60, |g| {
        let cfg = random_cfg(g, g.bool());
        let mut a = Autoscaler::new(cfg);
        let mut now = 0.0;
        for _ in 0..120 {
            let n = g.usize_in(2, cfg.max_devices.max(3));
            let loads = random_loads(g, n);
            let view = random_view(g);
            if let ScaleDecision::In { victim } = a.decide(now, &loads, 0, view) {
                let v = loads.iter().find(|l| l.idx == victim).unwrap();
                for l in loads.iter().filter(|l| l.drainable) {
                    prop_assert!(
                        v.cost >= l.cost,
                        "victim {victim} (cost {}) passed over the pricier \
                         drainable device {} (cost {})",
                        v.cost,
                        l.idx,
                        l.cost
                    );
                    if l.cost == v.cost && l.idx != v.idx {
                        prop_assert!(
                            v.busy <= l.busy,
                            "victim {victim} (busy {:.2}) is not the least \
                             busy of the max-cost drainables ({} at {:.2})",
                            v.busy,
                            l.idx,
                            l.busy
                        );
                    }
                }
            }
            now += g.f64_in(0.0, 2.0 * cfg.cooldown);
        }
        Ok(())
    });
}

#[test]
fn slo_mode_with_no_targets_degrades_to_util_mode_bit_identically() {
    check("slo-off degradation", 60, |g| {
        let cfg = random_cfg(g, false); // ttft_slo_ms == tpot_slo_ms == 0
        let mut a = Autoscaler::new(cfg);
        assert!(!a.slo_mode());
        let mut ref_cooldown = 0.0;
        let mut now = 0.0;
        for _ in 0..150 {
            let n = g.usize_in(1, cfg.max_devices + 1);
            let loads = random_loads(g, n);
            let backlog = g.usize_in(0, 10);
            // arbitrary SLO observations MUST be ignored with no targets
            let view = random_view(g);
            let got = a.decide(now, &loads, backlog, view);
            let want = util_reference(&cfg, &mut ref_cooldown, now, &loads, backlog);
            prop_assert!(
                got == want,
                "decisions diverged at t={now}: {got:?} vs util reference {want:?}"
            );
            prop_assert!(a.slo_gap(view) == 0.0, "gap must be 0 with no targets");
            now += g.f64_in(0.0, 2.0 * cfg.cooldown);
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// PR 10: proactive (forecast-driven) decisions
// ---------------------------------------------------------------------------

fn random_signal(g: &mut Gen) -> banaserve::forecast::ForecastSignal {
    banaserve::forecast::ForecastSignal {
        current_rate: g.f64_in(0.0, 50.0),
        predicted_rate: g.f64_in(0.0, 100.0),
        headroom: g.f64_in(0.0, 1.5),
    }
}

#[test]
fn proactive_decisions_respect_cooldown_and_fleet_bounds() {
    // the proactive path shares the reactive cooldown and the [min, max]
    // fleet bounds: over arbitrary load/SLO/forecast trajectories no two
    // non-Hold decisions land closer than `cooldown`, and replaying the
    // decisions against a synthetic fleet never escapes the bounds
    check("proactive cooldown+bounds", 40, |g| {
        let cfg = random_cfg(g, g.bool());
        let mut a = Autoscaler::new(cfg);
        let mut n = g.usize_in(cfg.min_devices.max(1), cfg.max_devices);
        let mut now = 0.0;
        let mut last_action: Option<f64> = None;
        for _ in 0..150 {
            let loads = random_loads(g, n);
            let view = random_view(g);
            // None interleaved with Some: an uncalibrated or disabled
            // forecaster must not unlock extra decisions either
            let sig = if g.bool() { Some(random_signal(g)) } else { None };
            let d = a.decide_proactive(now, &loads, g.usize_in(0, 8), view, sig);
            if d != ScaleDecision::Hold {
                if let Some(t) = last_action {
                    prop_assert!(
                        now >= t + cfg.cooldown - 1e-9,
                        "proactive decision at {now} only {}s after the one \
                         at {t} (cooldown {})",
                        now - t,
                        cfg.cooldown
                    );
                }
                last_action = Some(now);
            }
            match d {
                ScaleDecision::Out => {
                    prop_assert!(
                        n < cfg.max_devices,
                        "proactive scale-out at max fleet size {n} (max {})",
                        cfg.max_devices
                    );
                    n += 1;
                }
                ScaleDecision::In { victim } => {
                    prop_assert!(
                        n > cfg.min_devices && n > 1,
                        "proactive drain at fleet size {n} (min {})",
                        cfg.min_devices
                    );
                    let l = loads.iter().find(|l| l.idx == victim);
                    prop_assert!(
                        l.map(|l| l.drainable).unwrap_or(false),
                        "proactive victim {victim} is not drainable"
                    );
                    n -= 1;
                }
                ScaleDecision::Hold => {}
            }
            prop_assert!(
                n >= cfg.min_devices.max(1) && n <= cfg.max_devices,
                "fleet size {n} escaped [{}, {}] under proactive decisions",
                cfg.min_devices,
                cfg.max_devices
            );
            now += g.f64_in(0.0, 1.5 * cfg.cooldown);
        }
        Ok(())
    });
}

#[test]
fn proactive_with_no_signal_matches_reactive_bit_identically() {
    // decide_proactive(None) IS decide(): two autoscalers fed the same
    // trajectory, one through each entry point, never diverge
    check("proactive None delegation", 40, |g| {
        let cfg = random_cfg(g, g.bool());
        let mut a = Autoscaler::new(cfg);
        let mut b = Autoscaler::new(cfg);
        let mut now = 0.0;
        for _ in 0..120 {
            let n = g.usize_in(1, cfg.max_devices + 1);
            let loads = random_loads(g, n);
            let backlog = g.usize_in(0, 10);
            let view = random_view(g);
            let got = a.decide_proactive(now, &loads, backlog, view, None);
            let want = b.decide(now, &loads, backlog, view);
            prop_assert!(
                got == want,
                "decide_proactive(None) diverged from decide() at t={now}: \
                 {got:?} vs {want:?}"
            );
            now += g.f64_in(0.0, 2.0 * cfg.cooldown);
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// PR 6: fault-aware routing + deterministic fault plans
// ---------------------------------------------------------------------------

/// Every engine routes through the same pattern: maintain a full
/// [`LoadBook`], then hand policies the `filtered(|l|
/// devices[l.idx].is_active())` view. This property drives a random
/// fail/recover/drain/release trajectory over a small fleet and checks
/// that no policy's pick ever maps back to a non-Active device — the
/// invariant the chaos layer leans on to keep crashed and draining
/// devices out of the admission path.
#[test]
fn no_router_policy_picks_a_non_active_device() {
    check("router fault filtering", 60, |g| {
        let n = g.usize_in(2, 8);
        let spec = gpu_by_name("a100-80g").unwrap();
        let mut devices: Vec<Device> = (0..n)
            .map(|i| Device::new(i, spec.clone(), Role::Unified))
            .collect();
        let mut book = LoadBook::with_instances(n);
        let mut rr = RoundRobin::default();
        let mut aware = CacheAware { w_cache: 2.0, w_load: 1.0 };
        for step in 0..60 {
            // random membership transition (fault layer + elastic fleet)
            let d = g.usize_in(0, n - 1);
            match g.usize_in(0, 3) {
                0 => {
                    cluster::fail_device(&mut devices, d);
                }
                1 => {
                    cluster::recover_device(&mut devices, d);
                }
                2 => {
                    cluster::begin_drain(&mut devices, d);
                }
                _ => {
                    cluster::try_release(&mut devices, d, true);
                }
            }
            for i in 0..n {
                let e = book.entry_mut(i);
                e.load_seqs = g.usize_in(0, 20);
                e.queue_len = g.usize_in(0, 10);
                e.running = g.usize_in(0, 16);
                e.u = g.f64_in(0.0, 2.0);
                e.cache_hit = g.f64_in(0.0, 1.0);
                e.mem_free = g.usize_in(0, 1 << 30) as u64;
                e.weight = *g.pick(&[1.0, 1.0, 2.0]);
            }
            let view: Vec<_> = book
                .filtered(|l| devices[l.idx].is_active())
                .to_vec();
            let n_active = cluster::active_count(&devices);
            prop_assert!(
                view.len() == n_active,
                "filtered view has {} rows but {} devices are Active",
                view.len(),
                n_active
            );
            let picks = [
                ("round-robin", rr.pick(&view)),
                ("least-loaded", LeastLoaded.pick(&view)),
                ("least-queue", LeastQueue.pick(&view)),
                ("most-free-mem", MostFreeMem.pick(&view)),
                ("cache-aware", aware.pick(&view)),
                ("load-aware", pick_load_aware(&view, g.f64_in(0.1, 2.0), step)),
            ];
            for (name, pick) in picks {
                if let Some(pos) = pick {
                    prop_assert!(pos < view.len(), "{name}: pick {pos} out of range");
                    let idx = view[pos].idx;
                    prop_assert!(
                        devices[idx].state == DeviceState::Active,
                        "{name} picked device {idx} in state {:?}",
                        devices[idx].state
                    );
                } else {
                    prop_assert!(
                        view.is_empty(),
                        "{name} returned None with {} active candidates",
                        view.len()
                    );
                }
            }
        }
        Ok(())
    });
}

/// The chaos schedule must be a pure function of its inputs: identical
/// `(cfg, seed, n_devices, horizon)` gives a byte-identical plan (the
/// cross-engine fairness guarantee — every engine in a scenario cell sees
/// the same crashes at the same instants), and a different seed gives a
/// different plan (the generator actually consumes its seed).
#[test]
fn fault_plan_is_a_pure_function_of_its_seed() {
    check("fault plan determinism", 60, |g| {
        let mut cfg = FaultConfig::default();
        cfg.enabled = true;
        cfg.crash_mtbf = g.f64_in(1.0, 20.0);
        cfg.recovery_time = g.f64_in(0.5, 10.0);
        cfg.straggler_prob = g.f64_in(0.0, 1.0);
        cfg.straggler_secs = g.f64_in(0.5, 5.0);
        let seed = g.usize_in(0, 1 << 20) as u64;
        let n = g.usize_in(1, 12);
        let horizon = g.f64_in(10.0, 200.0);
        let a = FaultPlan::generate(&cfg, seed, n, horizon);
        let b = FaultPlan::generate(&cfg, seed, n, horizon);
        prop_assert!(
            a == b,
            "same (cfg, seed {seed}, {n} devices, {horizon:.1}s) produced \
             different schedules ({} vs {} events)",
            a.events.len(),
            b.events.len()
        );
        for w in a.events.windows(2) {
            prop_assert!(
                w[0].t <= w[1].t,
                "fault schedule out of order: {:.4} after {:.4}",
                w[1].t,
                w[0].t
            );
        }
        for ev in &a.events {
            prop_assert!(
                ev.device < n && ev.t >= 0.0,
                "event targets device {} of {n} at t={:.4}",
                ev.device,
                ev.t
            );
        }
        // a long enough horizon makes an empty schedule astronomically
        // unlikely, so a changed seed must actually change the plan
        if !a.events.is_empty() {
            let c = FaultPlan::generate(&cfg, seed ^ 0xDEAD_BEEF, n, horizon);
            prop_assert!(
                a != c,
                "seed {seed} and seed {} produced identical non-empty plans",
                seed ^ 0xDEAD_BEEF
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// PR 8: transfer-plane transactions — abort/rollback invariants
// ---------------------------------------------------------------------------

/// One knob set per case, so every engine in that case faces the same
/// chaos schedule (the fault plan is a pure function of cfg + seed).
struct ChaosKnobs {
    seed: u64,
    rps: f64,
    duration: f64,
    crash_mtbf: f64,
    link_mtbf: f64,
    partition_prob: f64,
    link_secs: f64,
    timeout_factor: f64,
    transfer_retries: u32,
    store_mtbf: f64,
    store_nodes: usize,
    store_replication: usize,
}

fn random_chaos(g: &mut Gen) -> ChaosKnobs {
    let store_nodes = g.usize_in(1, 3);
    ChaosKnobs {
        seed: g.usize_in(0, 1 << 16) as u64,
        rps: g.f64_in(4.0, 9.0),
        duration: g.f64_in(12.0, 20.0),
        crash_mtbf: g.f64_in(4.0, 12.0),
        link_mtbf: g.f64_in(1.5, 5.0),
        partition_prob: g.f64_in(0.5, 1.0),
        link_secs: g.f64_in(1.0, 3.0),
        timeout_factor: g.f64_in(1.5, 4.0),
        transfer_retries: g.usize_in(0, 3) as u32,
        store_mtbf: g.f64_in(4.0, 10.0),
        store_nodes,
        store_replication: g.usize_in(1, store_nodes),
    }
}

fn chaos_cfg(kind: EngineKind, k: &ChaosKnobs) -> ExperimentConfig {
    let mut c = ExperimentConfig::default_for(kind, "llama-13b", k.rps, k.seed);
    c.workload = WorkloadConfig::poisson(LengthProfile::AlpacaShort, k.rps, k.duration, k.seed);
    c.workload.prefix.share_prob = 0.6;
    c.warmup = 0.0;
    c.n_devices = 6;
    c.n_prefill = 3;
    c.fault.enabled = true;
    c.fault.crash_mtbf = k.crash_mtbf;
    c.fault.recovery_time = 2.0;
    c.fault.retry_budget = 3;
    c.fault.retry_backoff = 0.1;
    c.fault.link_mtbf = k.link_mtbf;
    c.fault.link_partition_prob = k.partition_prob;
    c.fault.link_fault_secs = k.link_secs;
    c.fault.transfer_timeout_factor = k.timeout_factor;
    c.fault.transfer_retries = k.transfer_retries;
    c.fault.store_crash_mtbf = k.store_mtbf;
    c.bana.store_nodes = k.store_nodes;
    c.bana.store_replication = k.store_replication;
    c
}

/// Every transfer transaction an engine opens — staging hand-off, P→D KV
/// transfer, layer/attention migration, scale-out spin-up — must resolve
/// (complete, or abort through its rollback path) by the time the event
/// queue drains, no matter how the link plane misbehaves. A live entry
/// after the drain is a leaked transaction: its timers fired without the
/// bookkeeping being released.
#[test]
fn transfer_transactions_always_drain_under_link_chaos() {
    check("transfer-plane drain", 6, |g| {
        let k = random_chaos(g);
        macro_rules! drained {
            ($Engine:ty, $kind:expr) => {{
                let c = chaos_cfg($kind, &k);
                let reqs = c.workload.generate();
                let mut e = <$Engine>::new(&c);
                let res = sim::run(&mut e, reqs, 1e6);
                if let Err(msg) = sim::check_conservation(&res, &mut e) {
                    return Err(format!("{:?} (seed {}): {msg}", $kind, k.seed));
                }
                prop_assert!(
                    e.inflight_transfers() == 0,
                    "{:?} (seed {}): {} transfer transactions still live \
                     after the queue drained",
                    $kind,
                    k.seed,
                    e.inflight_transfers()
                );
            }};
        }
        drained!(hft::HftEngine, EngineKind::HfStatic);
        drained!(vllm_sim::VllmEngine, EngineKind::Vllm);
        drained!(distserve_sim::DistServeEngine, EngineKind::DistServe);
        drained!(bana::BanaEngine, EngineKind::BanaServe);
        Ok(())
    });
}

/// BanaServe's abort paths must restore exact pre-transaction state: a
/// timed-out staging push or attention migration frees (or re-homes) the
/// KV it reserved, and an aborted layer migration discards its parked
/// share delta without applying any part of it. Observable residue after
/// a full drain — leaked device KV bytes, or `pinsts[i].share` out of
/// sync with `share_prefill[i]` — means a rollback path double-counted
/// or half-applied.
#[test]
fn banaserve_rollback_leaves_no_residue() {
    check("banaserve rollback residue", 8, |g| {
        let mut k = random_chaos(g);
        // partitions are the abort trigger — keep them likely
        k.partition_prob = g.f64_in(0.8, 1.0);
        let c = chaos_cfg(EngineKind::BanaServe, &k);
        let reqs = c.workload.generate();
        let mut e = bana::BanaEngine::new(&c);
        let res = sim::run(&mut e, reqs, 1e6);
        if let Err(msg) = sim::check_conservation(&res, &mut e) {
            return Err(format!("seed {}: {msg}", k.seed));
        }
        prop_assert!(
            e.inflight_transfers() == 0,
            "seed {}: {} transactions leaked past the drain",
            k.seed,
            e.inflight_transfers()
        );
        for (i, d) in e.devices.iter().enumerate() {
            prop_assert!(
                d.kv_bytes == 0,
                "seed {}: device {i} holds {} KV bytes after the drain — an \
                 aborted transfer failed to free or re-home its reservation",
                k.seed,
                d.kv_bytes
            );
        }
        for i in 0..e.devices.len() {
            prop_assert!(
                (e.pinsts[i].share - e.share_prefill[i]).abs() < 1e-9,
                "seed {}: device {i} pinst share {} diverged from \
                 share_prefill {} — a rolled-back layer migration leaked \
                 part of its share delta",
                k.seed,
                e.pinsts[i].share,
                e.share_prefill[i]
            );
        }
        Ok(())
    });
}
