//! Cross-engine integration: the relative-performance *shapes* the paper's
//! evaluation establishes must hold on fixed representative workloads.

use banaserve::config::{EngineKind, ExperimentConfig};
use banaserve::engines::run_experiment;
use banaserve::workload::{LengthProfile, WorkloadConfig};

fn cfg(kind: EngineKind, profile: LengthProfile, rps: f64, seed: u64) -> ExperimentConfig {
    let mut c = ExperimentConfig::default_for(kind, "llama-13b", rps, seed);
    c.workload = WorkloadConfig::poisson(profile, rps, 60.0, seed);
    c.warmup = 5.0;
    c
}

#[test]
fn fig8_shape_banaserve_beats_distserve_short_context_high_load() {
    let bana = run_experiment(&cfg(EngineKind::BanaServe, LengthProfile::AlpacaShort, 16.0, 7));
    let dist = run_experiment(&cfg(EngineKind::DistServe, LengthProfile::AlpacaShort, 16.0, 7));
    let ratio = bana.report.throughput_tok_s / dist.report.throughput_tok_s;
    assert!(
        ratio > 1.1,
        "paper Fig 8 shape: bana/distserve = {ratio:.2} (want > 1.1)"
    );
    assert!(
        bana.report.makespan < dist.report.makespan,
        "total time: bana {:.1}s vs dist {:.1}s",
        bana.report.makespan,
        dist.report.makespan
    );
}

#[test]
fn fig10_shape_banaserve_beats_distserve_long_context_high_load() {
    let bana = run_experiment(&cfg(EngineKind::BanaServe, LengthProfile::LongBench, 12.0, 7));
    let dist = run_experiment(&cfg(EngineKind::DistServe, LengthProfile::LongBench, 12.0, 7));
    let ratio = bana.report.throughput_tok_s / dist.report.throughput_tok_s;
    assert!(
        ratio > 1.1,
        "paper Fig 10 shape: bana/distserve = {ratio:.2} (want > 1.1)"
    );
}

#[test]
fn low_load_all_engines_comparable() {
    // paper: at 1-2 RPS the systems are close (gap grows with load)
    let rps = 2.0;
    let bana = run_experiment(&cfg(EngineKind::BanaServe, LengthProfile::AlpacaShort, rps, 9));
    let dist = run_experiment(&cfg(EngineKind::DistServe, LengthProfile::AlpacaShort, rps, 9));
    let vllm = run_experiment(&cfg(EngineKind::Vllm, LengthProfile::AlpacaShort, rps, 9));
    let ts = [
        bana.report.throughput_tok_s,
        dist.report.throughput_tok_s,
        vllm.report.throughput_tok_s,
    ];
    let max = ts.iter().cloned().fold(f64::MIN, f64::max);
    let min = ts.iter().cloned().fold(f64::MAX, f64::min);
    assert!(
        max / min < 1.25,
        "low-load spread too wide: {ts:?}"
    );
}

#[test]
fn fig1_shape_hft_underutilizes_versus_vllm() {
    let hft = run_experiment(&cfg(EngineKind::HfStatic, LengthProfile::AlpacaShort, 10.0, 5));
    let vllm = run_experiment(&cfg(EngineKind::Vllm, LengthProfile::AlpacaShort, 10.0, 5));
    assert!(
        vllm.report.throughput_tok_s > hft.report.throughput_tok_s,
        "vllm {:.0} must beat hft {:.0} tok/s",
        vllm.report.throughput_tok_s,
        hft.report.throughput_tok_s
    );
}

#[test]
fn fig2a_shape_cache_skew_versus_load_aware_balance() {
    // vLLM's cache-aware router must skew routed counts far more than
    // BanaServe's load-aware router on the same skew-heavy workload.
    let mk = |kind| {
        let mut c = cfg(kind, LengthProfile::AlpacaShort, 12.0, 3);
        c.workload.prefix.share_prob = 0.95;
        c.workload.prefix.n_templates = 3;
        c.workload.prefix.zipf_s = 1.5;
        c.workload.prefix.shared_frac = (0.8, 0.95);
        c.workload.duration = 20.0;
        c.warmup = 0.0;
        c.bana.layer_migration = false;
        c.bana.attention_migration = false;
        c
    };
    let skew = |counts: &[u64]| {
        let max = *counts.iter().max().unwrap() as f64;
        let min = *counts.iter().filter(|&&c| c > 0).min().unwrap_or(&1) as f64;
        max / min.max(1.0)
    };
    let vllm = run_experiment(&mk(EngineKind::Vllm));
    let bana = run_experiment(&mk(EngineKind::BanaServe));
    let vllm_skew = skew(&vllm.extras.routed_counts);
    // BanaServe routes only across the prefill pool
    let bana_counts: Vec<u64> = bana
        .extras
        .routed_counts
        .iter()
        .cloned()
        .filter(|&c| c > 0)
        .collect();
    let bana_skew = skew(&bana_counts);
    assert!(
        vllm_skew > 1.8 * bana_skew,
        "cache-aware skew {vllm_skew:.2} should far exceed load-aware {bana_skew:.2}"
    );
}

#[test]
fn global_store_ablation_reduces_cached_tokens() {
    let mut with = cfg(EngineKind::BanaServe, LengthProfile::AlpacaShort, 8.0, 13);
    with.workload.prefix.share_prob = 0.8;
    let mut without = with.clone();
    without.bana.global_store = false;
    let w = run_experiment(&with);
    let wo = run_experiment(&without);
    assert!(w.extras.store_hit_rate > 0.2, "store should hit");
    assert_eq!(wo.extras.store_hit_rate, 0.0);
    assert!(
        w.report.cached_tokens > wo.report.cached_tokens,
        "store must increase cache reuse"
    );
}

#[test]
fn migration_ablation_degrades_throughput_under_pressure() {
    // disabling both migration granularities must not HELP at saturation
    let base = cfg(EngineKind::BanaServe, LengthProfile::AlpacaShort, 18.0, 21);
    let mut off = base.clone();
    off.bana.layer_migration = false;
    off.bana.attention_migration = false;
    let on = run_experiment(&base);
    let off = run_experiment(&off);
    assert!(
        on.report.throughput_tok_s >= off.report.throughput_tok_s * 0.98,
        "migration hurt: on {:.0} vs off {:.0}",
        on.report.throughput_tok_s,
        off.report.throughput_tok_s
    );
    assert!(on.extras.layer_migrations > 0, "migration should engage");
}

#[test]
fn opt13b_also_runs_all_engines() {
    // cross-architecture validation (paper Table 1 / Fig 9, 11)
    for kind in [EngineKind::Vllm, EngineKind::DistServe, EngineKind::BanaServe] {
        let mut c = ExperimentConfig::default_for(kind, "opt-13b", 6.0, 3);
        c.workload = WorkloadConfig::poisson(LengthProfile::AlpacaShort, 6.0, 20.0, 3);
        c.warmup = 0.0;
        let out = run_experiment(&c);
        assert!(
            out.report.n_requests > 0 && out.report.throughput_tok_s > 0.0,
            "{} failed on opt-13b",
            c.engine.name()
        );
    }
}
