//! Engine-level forecast-subsystem suite (PR 10):
//!
//! * **Zero cost when off** — with `forecast.mode = Off` (the default),
//!   every other forecast knob — window, alpha, horizon, headroom,
//!   period, warm_start — is never read on any code path: scrambling
//!   them changes nothing in the output, byte for byte, for all four
//!   engines. This is the bit-identity contract the `Option<RateForecaster>`
//!   plumbing exists to keep.
//! * **Replay determinism** — a proactive elastic run replays a
//!   byte-identical `Report` from the same seed (the forecaster is a pure
//!   function of observed arrivals).
//! * **Signal plumbing** — proactive runs actually record the forecast:
//!   `forecast_series` / `actual_rate_series` are non-empty for every
//!   engine, and empty with the mode off.
//! * **Warm-start accounting** — on a bursty elastic BanaServe run that
//!   scales out, the warm arm prefetches store prefixes
//!   (`warm_prefetch_tokens > 0`) and never loses requests to it.

use banaserve::config::{EngineKind, ExperimentConfig, ForecastMode};
use banaserve::engines::{run_experiment, ExperimentOutcome};
use banaserve::workload::{ArrivalProcess, LengthProfile, WorkloadConfig};

const ALL_ENGINES: [EngineKind; 4] = [
    EngineKind::HfStatic,
    EngineKind::Vllm,
    EngineKind::DistServe,
    EngineKind::BanaServe,
];

fn base_cfg(kind: EngineKind, rps: f64, seed: u64) -> ExperimentConfig {
    let mut c = ExperimentConfig::default_for(kind, "llama-13b", rps, seed);
    c.workload = WorkloadConfig::poisson(LengthProfile::AlpacaShort, rps, 30.0, seed);
    c.warmup = 0.0;
    c.n_devices = 4;
    c.n_prefill = 2;
    c
}

/// An elastic bursty config that reliably scales out (the shape the
/// engines' own `burst must trigger scale-out` tests use), with the
/// forecaster on.
fn proactive_cfg(kind: EngineKind, seed: u64) -> ExperimentConfig {
    let mut c = base_cfg(kind, 5.0, seed);
    c.n_devices = 2;
    c.n_prefill = 1;
    c.workload.duration = 60.0;
    // the burst shape integration_fleet.rs proves triggers scale-out
    c.workload.arrivals = ArrivalProcess::Bursty {
        rps: 5.0,
        burst_factor: 5.0,
        burst_secs: 12.0,
        period_secs: 48.0,
    };
    c.workload.prefix.share_prob = 0.6;
    c.autoscale.enabled = true;
    c.autoscale.min_devices = 2;
    c.autoscale.max_devices = 6;
    c.forecast.mode = ForecastMode::Proactive;
    c
}

fn fingerprint(out: &ExperimentOutcome) -> String {
    format!("{:?} | {:?} | {:?}", out.report, out.device_util, out.extras)
}

#[test]
fn forecast_knobs_are_inert_while_off() {
    for kind in ALL_ENGINES {
        let clean = run_experiment(&base_cfg(kind, 8.0, 7));
        // scramble every knob except the mode switch: none of them may be
        // read on any code path while forecasting is off
        let mut scrambled = base_cfg(kind, 8.0, 7);
        scrambled.forecast.window = 0.25;
        scrambled.forecast.alpha = 0.95;
        scrambled.forecast.horizon = 99.0;
        scrambled.forecast.headroom = 0.01;
        scrambled.forecast.period = 123.0;
        scrambled.forecast.warm_start = true;
        let off = run_experiment(&scrambled);
        assert_eq!(
            fingerprint(&clean),
            fingerprint(&off),
            "{:?}: disabled forecaster must be invisible in the output",
            kind
        );
        assert!(clean.extras.forecast_series.is_empty());
        assert!(clean.extras.actual_rate_series.is_empty());
        assert_eq!(clean.extras.warm_prefetch_tokens, 0);
    }

    // same contract on an ELASTIC fleet: the reactive autoscaler's
    // decisions must not shift either
    for kind in ALL_ENGINES {
        let mut reactive = proactive_cfg(kind, 13);
        reactive.forecast.mode = ForecastMode::Off;
        let clean = run_experiment(&reactive);
        let mut scrambled = reactive.clone();
        scrambled.forecast.horizon = 42.0;
        scrambled.forecast.headroom = 0.05;
        scrambled.forecast.warm_start = true;
        let off = run_experiment(&scrambled);
        assert_eq!(
            fingerprint(&clean),
            fingerprint(&off),
            "{:?}: forecast knobs must be inert on the reactive elastic path",
            kind
        );
    }
}

#[test]
fn proactive_runs_replay_deterministically_and_record_the_forecast() {
    for kind in ALL_ENGINES {
        let a = run_experiment(&proactive_cfg(kind, 21));
        let b = run_experiment(&proactive_cfg(kind, 21));
        assert_eq!(
            fingerprint(&a),
            fingerprint(&b),
            "{:?}: same seed must replay the same proactive run",
            kind
        );
        assert!(
            !a.extras.forecast_series.is_empty(),
            "{:?}: proactive run recorded no forecast points",
            kind
        );
        assert!(
            !a.extras.actual_rate_series.is_empty(),
            "{:?}: proactive run recorded no rate observations",
            kind
        );
        for &(_, r) in &a.extras.actual_rate_series {
            assert!(r.is_finite() && r >= 0.0, "{:?}: bad measured rate {r}", kind);
        }
        for &(_, p) in &a.extras.forecast_series {
            assert!(p.is_finite() && p >= 0.0, "{:?}: bad predicted rate {p}", kind);
        }
    }
}

#[test]
fn warm_start_prefetches_into_scaled_out_devices() {
    let mut c = proactive_cfg(EngineKind::BanaServe, 5);
    c.forecast.warm_start = true;
    let out = run_experiment(&c);
    // run_experiment panics on a conservation violation, so reaching here
    // is the safety half; the accounting half needs a scale-out to happen
    assert!(
        out.extras.scale_outs > 0,
        "burst must trigger scale-out (got none — the warm path never ran)"
    );
    assert!(
        out.extras.warm_prefetch_tokens > 0,
        "warm-start scale-out on a shared-prefix trace prefetched nothing"
    );

    // warm-start is store-powered: without the Global KV Store the knob
    // must quietly disarm rather than invent prefetch work
    let mut no_store = c.clone();
    no_store.bana.global_store = false;
    let bare = run_experiment(&no_store);
    assert_eq!(
        bare.extras.warm_prefetch_tokens, 0,
        "warm-start without the store must prefetch nothing"
    );
}
