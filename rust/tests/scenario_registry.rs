//! Registry smoke gate: every registered scenario must run end-to-end in
//! a tiny mode (≤2 s of simulated trace, 1 seed) and emit a JSON document
//! whose rows carry exactly the keys its spec declares — the schema each
//! spec publishes IS the schema it writes. Capability gates are expected
//! to fail on a 2 s trace, so the exit code is not asserted here (the CI
//! workflow runs `cache-skew` at full scale for the capability proof).

use banaserve::scenario::{self, ScenarioSpec};
use banaserve::util::args::Args;
use banaserve::util::json;
use std::path::PathBuf;

fn tiny_args(out_dir: &str) -> Args {
    Args::parse(
        format!("--duration 2 --seeds 1 --rps 3 --threads 2 --out-dir {out_dir}")
            .split_whitespace()
            .map(String::from),
    )
}

fn smoke(spec: &ScenarioSpec) -> json::Value {
    let out_dir: PathBuf = std::env::temp_dir().join(format!(
        "banaserve-scenario-smoke-{}-{}",
        std::process::id(),
        spec.name
    ));
    let dir = out_dir.to_str().expect("utf-8 temp dir");
    let code = scenario::run(spec, &tiny_args(dir));
    assert!(
        code != 2,
        "{}: tiny-mode run must not fail flag/plan validation",
        spec.name
    );
    let path = out_dir.join(spec.out_file);
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{}: no JSON at {}: {e}", spec.name, path.display()));
    let doc = json::parse(&text)
        .unwrap_or_else(|e| panic!("{}: emitted invalid JSON: {e}", spec.name));
    let _ = std::fs::remove_dir_all(&out_dir);
    doc
}

fn validate_schema(spec: &ScenarioSpec, doc: &json::Value) {
    assert_eq!(
        doc.get("scenario").and_then(|v| v.as_str()),
        Some(spec.name),
        "{}: scenario tag",
        spec.name
    );
    let seeds = doc.get("seeds").and_then(|v| v.as_arr()).expect("seeds array");
    assert_eq!(seeds.len(), 1, "{}: --seeds 1 must yield one seed", spec.name);
    let rows = doc.get("results").and_then(|v| v.as_arr()).expect("results array");
    assert!(!rows.is_empty(), "{}: no result rows", spec.name);
    for (i, row) in rows.iter().enumerate() {
        for key in spec.row_schema_keys() {
            assert!(
                row.get(&key).is_some(),
                "{} row {i}: missing declared key '{key}'",
                spec.name
            );
        }
    }
    let sums = doc.get("summary").and_then(|v| v.as_arr()).expect("summary array");
    assert!(!sums.is_empty(), "{}: no summary rows", spec.name);
    for (i, row) in sums.iter().enumerate() {
        for key in spec.summary_schema_keys() {
            assert!(
                row.get(&key).is_some(),
                "{} summary row {i}: missing declared key '{key}'",
                spec.name
            );
        }
    }
}

#[test]
fn every_registered_scenario_runs_tiny_and_matches_its_schema() {
    for spec in scenario::REGISTRY.iter() {
        let doc = smoke(spec);
        validate_schema(spec, &doc);
    }
}

#[test]
fn scenario_rejects_unknown_flags() {
    let spec = scenario::by_name("bursty-autoscale").unwrap();
    let a = Args::parse(
        "--duration 2 --seeds 1 --base-devicess 3"
            .split_whitespace()
            .map(String::from),
    );
    assert_eq!(scenario::run(spec, &a), 2, "typo'd flag must abort the run");
}

#[test]
fn fault_recovery_grid_crashes_all_four_engines_identically() {
    // the robustness gate only means something if every engine faces the
    // SAME seeded crash schedule: all four engines, fault layer on, and
    // identical fault knobs in every generated cell config
    let spec = scenario::by_name("fault-recovery").unwrap();
    let plan = (spec.build)(&tiny_args("unused")).unwrap();
    let engines: Vec<&str> = plan.engines.iter().map(|e| e.name()).collect();
    assert_eq!(engines, vec!["hft", "vllm", "distserve", "banaserve"]);
    assert_eq!(plan.variants.len(), 1);
    for &kind in &plan.engines {
        let cfg = (plan.make_cfg)(kind, &plan.variants[0], 11);
        assert!(cfg.fault.enabled, "{}: fault layer must be on", kind.name());
        assert_eq!(cfg.workload.seed, 11);
        assert!(
            cfg.fault.crash_mtbf > 0.0 && cfg.fault.recovery_time > 0.0,
            "{}: degenerate fault knobs",
            kind.name()
        );
    }
}

#[test]
fn tiered_store_grid_isolates_the_store_shape() {
    // three BanaServe-only variants over the SAME workload; only the
    // store's tier budgets differ, and the flat variants really are flat
    // (zero SSD capacity -> no demotion path at all)
    let spec = scenario::by_name("tiered-store").unwrap();
    let plan = (spec.build)(&tiny_args("unused")).unwrap();
    let engines: Vec<&str> = plan.engines.iter().map(|e| e.name()).collect();
    assert_eq!(engines, vec!["banaserve"]);
    let labels: Vec<&str> = plan.variants.iter().map(|v| v.label).collect();
    assert_eq!(labels, vec!["tiered", "flat-small", "flat-large"]);
    let cfg_of = |i: usize| (plan.make_cfg)(plan.engines[0], &plan.variants[i], 13);
    let (t, fs, fl) = (cfg_of(0), cfg_of(1), cfg_of(2));
    assert_eq!(t.bana.store_cpu_tokens, fs.bana.store_cpu_tokens);
    assert!(t.bana.store_ssd_tokens > 0);
    assert_eq!(fs.bana.store_ssd_tokens, 0);
    assert_eq!(fl.bana.store_ssd_tokens, 0);
    assert_eq!(
        fl.bana.store_cpu_tokens,
        t.bana.store_cpu_tokens + t.bana.store_ssd_tokens,
        "flat-large must hold the tiered variant's total capacity in DRAM"
    );
    // identical trace across variants: the workload knobs must not depend
    // on the variant label
    assert_eq!(t.workload.seed, 13);
    assert_eq!(t.workload.prefix.share_prob, fs.workload.prefix.share_prob);
    assert!(t.workload.prefix.n_templates >= 20, "needs a wide working set");
}

#[test]
fn predictive_autoscale_grid_isolates_the_forecast_knobs() {
    // three elastic variants over the SAME diurnal workload; only the
    // forecast knobs differ — reactive-cold must carry the bit-identical
    // default (mode off, no warm-start), and warm-start is exclusive to
    // the proactive-warm arm
    use banaserve::config::ForecastMode;
    let spec = scenario::by_name("predictive-autoscale").unwrap();
    let plan = (spec.build)(&tiny_args("unused")).unwrap();
    let engines: Vec<&str> = plan.engines.iter().map(|e| e.name()).collect();
    assert_eq!(engines, vec!["banaserve", "distserve"]);
    let labels: Vec<&str> = plan.variants.iter().map(|v| v.label).collect();
    assert_eq!(labels, vec!["reactive-cold", "proactive-cold", "proactive-warm"]);
    let cfg_of = |i: usize| (plan.make_cfg)(plan.engines[0], &plan.variants[i], 17);
    let (re, pc, pw) = (cfg_of(0), cfg_of(1), cfg_of(2));
    assert_eq!(re.forecast.mode, ForecastMode::Off);
    assert!(!re.forecast.warm_start);
    assert_eq!(pc.forecast.mode, ForecastMode::Proactive);
    assert!(!pc.forecast.warm_start);
    assert_eq!(pw.forecast.mode, ForecastMode::Proactive);
    assert!(pw.forecast.warm_start);
    for c in [&re, &pc, &pw] {
        assert!(c.autoscale.enabled, "every arm is elastic");
        assert_eq!(c.workload.seed, 17);
        assert!(
            matches!(
                c.workload.arrivals,
                banaserve::workload::ArrivalProcess::Diurnal { .. }
            ),
            "the forecaster's seasonal fit needs the diurnal trace"
        );
    }
}

#[test]
fn cache_skew_grid_covers_both_routers() {
    // the new scenario's grid is (vllm, banaserve) × one static variant —
    // the registry must expose that shape so the CI tiny run exercises
    // both routers
    let spec = scenario::by_name("cache-skew").unwrap();
    let plan = (spec.build)(&tiny_args("unused")).unwrap();
    let engines: Vec<&str> = plan.engines.iter().map(|e| e.name()).collect();
    assert_eq!(engines, vec!["vllm", "banaserve"]);
    assert_eq!(plan.variants.len(), 1);
    let cfg = (plan.make_cfg)(plan.engines[0], &plan.variants[0], 7);
    assert!(cfg.workload.prefix.share_prob > 0.5, "needs shared prefixes");
    assert_eq!(cfg.workload.seed, 7);
}
