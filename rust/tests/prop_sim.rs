//! Event-queue equivalence gate: the calendar queue the driver runs on
//! must drain in EXACTLY the same `(time, kind)` order as the `BinaryHeap`
//! reference implementation, over randomized dense-tie event streams —
//! duplicate timestamps, zero-delay `push_after`, arrivals interleaved
//! with timers, pops interleaved with pushes, and far-future events that
//! cross calendar-year boundaries.

use banaserve::prop_assert;
use banaserve::sim::{EventKind, EventQueue, HeapEventQueue, Timer};
use banaserve::util::checker::check;
use banaserve::workload::Request;

/// Order-relevant identity of a drained event: `(kind, tag, a, b)` for
/// timers, `(kind, id, ..)` for arrivals.
fn key(kind: &EventKind) -> (u64, u64, u64, u64) {
    match kind {
        EventKind::Arrival(r) => (0, r.id, r.prompt_len, r.output_len),
        EventKind::Timer(t) => (1, t.tag, t.a, t.b),
    }
}

fn pop_both(cal: &mut EventQueue, heap: &mut HeapEventQueue) -> Result<bool, String> {
    match (cal.pop(), heap.pop()) {
        (None, None) => Ok(false),
        (Some((ta, ka)), Some((tb, kb))) => {
            prop_assert!(
                ta == tb && key(&ka) == key(&kb),
                "drain order diverged: calendar ({ta}, {:?}) vs heap ({tb}, {:?})",
                key(&ka),
                key(&kb)
            );
            prop_assert!(
                cal.now() == heap.now(),
                "clocks diverged: {} vs {}",
                cal.now(),
                heap.now()
            );
            Ok(true)
        }
        (a, b) => Err(format!(
            "one queue drained early: calendar={:?} heap={:?}",
            a.map(|(t, k)| (t, key(&k))),
            b.map(|(t, k)| (t, key(&k)))
        )),
    }
}

#[test]
fn calendar_queue_drains_identically_to_heap_reference() {
    check("calendar vs heap drain order", 80, |g| {
        let mut cal = EventQueue::new();
        let mut heap = HeapEventQueue::new();
        // a small palette of times makes exact duplicate timestamps common
        // — the dense-tie case where only the seq tiebreak orders events
        let palette: Vec<f64> = (0..g.usize_in(1, 6)).map(|_| g.f64_in(0.0, 8.0)).collect();
        let ops = g.usize_in(1, g.size.max(4) * 4);
        let mut next_id = 0u64;
        for op in 0..ops {
            let tag = op as u64;
            match g.usize_in(0, 6) {
                0 | 1 => {
                    // timer at a tie-prone absolute time (clamped to now)
                    let at = g.pick(&palette).max(cal.now());
                    let t = Timer::with(tag, tag ^ 0xA5, 7);
                    cal.push_timer(at, t);
                    heap.push_timer(at, t);
                }
                2 => {
                    // zero-delay push_after: fires at now, ordered by seq
                    let t = Timer::new(tag);
                    cal.push_after(0.0, t);
                    heap.push_after(0.0, t);
                }
                3 => {
                    // arrival interleaved with the timer stream
                    let req = Request {
                        id: next_id,
                        arrival: g.f64_in(0.0, 8.0).max(cal.now()),
                        prompt_len: 8 + next_id,
                        output_len: 2,
                        cache_tokens: vec![1, 2].into(),
                    };
                    next_id += 1;
                    cal.push_arrival(req.clone());
                    heap.push_arrival(req);
                }
                4 => {
                    // far-future timer: beyond one calendar year, forcing
                    // year re-anchors and `far` redistribution
                    let at = cal.now() + g.f64_in(2.0, 60.0);
                    let t = Timer::with(tag, 1, 2);
                    cal.push_timer(at, t);
                    heap.push_timer(at, t);
                }
                _ => {
                    // interleaved pop
                    pop_both(&mut cal, &mut heap)?;
                }
            }
            prop_assert!(
                cal.len() == heap.len(),
                "lengths diverged: {} vs {}",
                cal.len(),
                heap.len()
            );
        }
        // drain both to empty in lockstep
        while pop_both(&mut cal, &mut heap)? {}
        prop_assert!(
            cal.is_empty() && heap.is_empty(),
            "queues not empty after drain"
        );
        Ok(())
    });
}

#[test]
fn calendar_queue_total_drain_is_sorted_by_time() {
    // independent of the reference: a full drain must be time-sorted with
    // insertion order breaking ties
    check("calendar drain sorted", 40, |g| {
        let mut q = EventQueue::new();
        let n = g.usize_in(1, 300);
        for i in 0..n {
            // mix of dense ties, in-year spread, and cross-year jumps
            let t = match g.usize_in(0, 2) {
                0 => 1.0,
                1 => g.f64_in(0.0, 2.0),
                _ => g.f64_in(0.0, 50.0),
            };
            q.push_timer(t, Timer::new(i as u64));
        }
        let mut last_t = f64::NEG_INFINITY;
        let mut seen_at_t: Vec<u64> = Vec::new();
        let mut drained = 0;
        while let Some((t, EventKind::Timer(tm))) = q.pop() {
            prop_assert!(t >= last_t, "time went backwards: {t} < {last_t}");
            if t == last_t {
                if let Some(&prev) = seen_at_t.last() {
                    prop_assert!(
                        prev < tm.tag,
                        "tie at t={t} fired out of insertion order: {seen_at_t:?} then {}",
                        tm.tag
                    );
                }
            } else {
                seen_at_t.clear();
            }
            seen_at_t.push(tm.tag);
            last_t = t;
            drained += 1;
        }
        prop_assert!(drained == n, "drained {drained} of {n}");
        Ok(())
    });
}
