//! Fleet-layer integration gates:
//!
//! * **Behavior preservation** — with autoscaling disabled, a fixed-seed
//!   run of each of the four engines must produce a `Report` identical to
//!   the committed golden snapshot (`tests/golden/engine_reports.json`).
//!   The snapshot self-seeds: the first run on a toolchain writes it, every
//!   later run (and every refactor) is compared bit-for-bit against it.
//! * **Elastic capability** — on a bursty trace, the autoscaled BanaServe
//!   fleet must beat the base-provisioned static fleet's P99 total
//!   processing time, scale out during bursts, and strand nothing.

use banaserve::config::{EngineKind, ExperimentConfig};
use banaserve::engines::run_experiment;
use banaserve::util::json::{self, Value};
use banaserve::workload::{ArrivalProcess, LengthProfile, WorkloadConfig};
use std::path::PathBuf;

fn fixed_cfg(kind: EngineKind) -> ExperimentConfig {
    let mut c = ExperimentConfig::default_for(kind, "llama-13b", 6.0, 1234);
    c.workload = WorkloadConfig::poisson(LengthProfile::AlpacaShort, 6.0, 25.0, 1234);
    c.warmup = 0.0;
    c
}

/// Every Report field that must survive a refactor, as a JSON object.
fn fingerprint(kind: EngineKind) -> Value {
    let out = run_experiment(&fixed_cfg(kind));
    let r = &out.report;
    json::obj(vec![
        ("submitted", json::num(out.submitted as f64)),
        ("n_requests", json::num(r.n_requests as f64)),
        ("dropped", json::num(r.dropped as f64)),
        ("output_tokens", json::num(r.output_tokens as f64)),
        ("input_tokens", json::num(r.input_tokens as f64)),
        ("cached_tokens", json::num(r.cached_tokens as f64)),
        ("makespan", json::num(r.makespan)),
        ("throughput_tok_s", json::num(r.throughput_tok_s)),
        ("ttft_mean", json::num(r.ttft.mean())),
        ("tpot_mean", json::num(r.tpot.mean())),
        ("e2e_mean", json::num(r.e2e.mean())),
        ("queue_mean", json::num(r.queue.mean())),
    ])
}

#[test]
fn behavior_preserved_against_golden_snapshots() {
    let path =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/engine_reports.json");
    let kinds = [
        EngineKind::HfStatic,
        EngineKind::Vllm,
        EngineKind::DistServe,
        EngineKind::BanaServe,
    ];
    let current = json::obj(
        kinds
            .iter()
            .map(|&k| (k.name(), fingerprint(k)))
            .collect(),
    );
    if !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, json::write(&current)).unwrap();
        eprintln!(
            "[behavior gate] golden snapshot seeded at {} — commit it; future \
             runs compare against it",
            path.display()
        );
        return;
    }
    let golden = json::parse(&std::fs::read_to_string(&path).unwrap())
        .expect("golden snapshot must parse");
    for &k in &kinds {
        let want = golden
            .get(k.name())
            .unwrap_or_else(|| panic!("golden snapshot missing engine {}", k.name()));
        let got = current.get(k.name()).unwrap();
        let obj = want.as_obj().expect("engine entry is an object");
        for (field, expect) in obj.iter() {
            let e = expect.as_f64().expect("golden fields are numeric");
            let g = got
                .get(field)
                .and_then(|v| v.as_f64())
                .unwrap_or_else(|| panic!("missing field {field} for {}", k.name()));
            assert!(
                (e - g).abs() <= 1e-9 * e.abs().max(1.0),
                "{} {field}: golden {e} != current {g} — the refactor changed \
                 behavior (delete the snapshot ONLY for an intentional change)",
                k.name()
            );
        }
    }
}

fn bursty_cfg(kind: EngineKind, devices: usize, elastic: bool, seed: u64) -> ExperimentConfig {
    let mut c = ExperimentConfig::default_for(kind, "llama-13b", 5.0, seed);
    c.n_devices = devices;
    c.n_prefill = (devices / 2).max(1);
    c.warmup = 0.0;
    c.workload = WorkloadConfig::poisson(LengthProfile::AlpacaShort, 5.0, 120.0, seed);
    c.workload.arrivals = ArrivalProcess::Bursty {
        rps: 5.0,
        burst_factor: 5.0,
        burst_secs: 12.0,
        period_secs: 48.0,
    };
    if elastic {
        c.autoscale.enabled = true;
        c.autoscale.min_devices = devices;
        c.autoscale.max_devices = 6;
    }
    c
}

#[test]
fn elastic_fleet_beats_static_base_p99_on_bursty_trace() {
    // The capability gate: same bursty trace, same base fleet of 2 devices;
    // the elastic run may scale to 6 during bursts. It must strictly beat
    // the static fleet's P99 total processing time.
    let stat = run_experiment(&bursty_cfg(EngineKind::BanaServe, 2, false, 11));
    let ela = run_experiment(&bursty_cfg(EngineKind::BanaServe, 2, true, 11));
    assert_eq!(
        stat.submitted,
        stat.report.n_requests + stat.report.dropped,
        "static run must account for every request"
    );
    assert_eq!(
        ela.submitted,
        ela.report.n_requests + ela.report.dropped,
        "elastic run must account for every request"
    );
    assert!(
        ela.extras.scale_outs > 0,
        "bursts must trigger scale-out (got {:?})",
        ela.extras.scale_outs
    );
    let mut rs = stat.report;
    let mut re = ela.report;
    let (p_stat, p_ela) = (rs.e2e.p99(), re.e2e.p99());
    assert!(
        p_ela < p_stat,
        "elastic P99 {p_ela:.2}s must beat static-base P99 {p_stat:.2}s"
    );
    // the fleet-size series must record the scaling trajectory
    let peak = ela
        .extras
        .fleet_size_series
        .iter()
        .map(|&(_, v)| v)
        .fold(0.0, f64::max);
    assert!(peak > 2.0, "fleet must have grown past its base size");
}

#[test]
fn distserve_elastic_fleet_runs_and_conserves() {
    let out = run_experiment(&bursty_cfg(EngineKind::DistServe, 2, true, 11));
    assert_eq!(out.submitted, out.report.n_requests + out.report.dropped);
    assert!(
        out.extras.scale_outs > 0,
        "bursty trace must trigger distserve scale-out"
    );
}

#[test]
fn autoscaler_drain_path_never_strands_requests() {
    // Aggressive scale-in thresholds force repeated drain/release cycles
    // between bursts; every admitted request must still complete
    // (run_experiment panics on conservation violations).
    for seed in [1, 2, 3] {
        let mut c = bursty_cfg(EngineKind::BanaServe, 3, true, seed);
        c.autoscale.min_devices = 2;
        c.autoscale.max_devices = 5;
        c.autoscale.scale_in_util = 0.9; // drain whenever not saturated
        c.autoscale.scale_out_util = 0.95;
        c.autoscale.cooldown = 1.0;
        c.bana.control_period = 0.5;
        c.workload.duration = 60.0;
        let out = run_experiment(&c);
        assert_eq!(
            out.submitted,
            out.report.n_requests + out.report.dropped,
            "seed {seed}: requests stranded by the drain path"
        );
    }
}

#[test]
fn static_runs_are_deterministic_across_repeats() {
    // the golden gate relies on run-to-run determinism; make it explicit
    for kind in [EngineKind::Vllm, EngineKind::BanaServe] {
        let a = run_experiment(&fixed_cfg(kind));
        let b = run_experiment(&fixed_cfg(kind));
        assert_eq!(a.report.n_requests, b.report.n_requests);
        assert!(
            (a.report.throughput_tok_s - b.report.throughput_tok_s).abs() < 1e-9,
            "{:?} nondeterministic",
            kind
        );
        assert!((a.report.e2e.mean() - b.report.e2e.mean()).abs() < 1e-9);
    }
}
