//! Fleet-layer integration gates:
//!
//! * **Behavior preservation** — with autoscaling disabled, a fixed-seed
//!   run of each of the four engines must produce a `Report` identical to
//!   the committed golden snapshot (`tests/golden/engine_reports.json`).
//!   The snapshot self-seeds: the first run on a toolchain writes it, every
//!   later run (and every refactor) is compared bit-for-bit against it.
//! * **Elastic capability** — on a bursty trace, the autoscaled BanaServe
//!   fleet must beat the base-provisioned static fleet's P99 total
//!   processing time, scale out during bursts, and strand nothing.

use banaserve::config::{EngineKind, ExperimentConfig};
use banaserve::engines::run_experiment;
use banaserve::util::json::{self, Value};
use banaserve::workload::{ArrivalProcess, LengthProfile, WorkloadConfig};
use std::path::PathBuf;

fn fixed_cfg(kind: EngineKind) -> ExperimentConfig {
    let mut c = ExperimentConfig::default_for(kind, "llama-13b", 6.0, 1234);
    c.workload = WorkloadConfig::poisson(LengthProfile::AlpacaShort, 6.0, 25.0, 1234);
    c.warmup = 0.0;
    c
}

/// Fixed-seed ELASTIC config for the newly-elastic monolithic engines —
/// the golden gate pins their autoscaling trajectory too (util mode, so
/// the snapshot does not depend on SLO windowing).
fn fixed_elastic_cfg(kind: EngineKind) -> ExperimentConfig {
    let mut c = fixed_cfg(kind);
    c.n_devices = 2;
    c.autoscale.enabled = true;
    c.autoscale.min_devices = 2;
    c.autoscale.max_devices = 5;
    c
}

/// Every Report field that must survive a refactor, as a JSON object.
fn fingerprint(cfg: &ExperimentConfig) -> Value {
    let out = run_experiment(cfg);
    let r = &out.report;
    json::obj(vec![
        ("submitted", json::num(out.submitted as f64)),
        ("n_requests", json::num(r.n_requests as f64)),
        ("dropped", json::num(r.dropped as f64)),
        ("output_tokens", json::num(r.output_tokens as f64)),
        ("input_tokens", json::num(r.input_tokens as f64)),
        ("cached_tokens", json::num(r.cached_tokens as f64)),
        ("makespan", json::num(r.makespan)),
        ("throughput_tok_s", json::num(r.throughput_tok_s)),
        ("ttft_mean", json::num(r.ttft.mean())),
        ("tpot_mean", json::num(r.tpot.mean())),
        ("e2e_mean", json::num(r.e2e.mean())),
        ("queue_mean", json::num(r.queue.mean())),
    ])
}

#[test]
fn behavior_preserved_against_golden_snapshots() {
    let path =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/engine_reports.json");
    let kinds = [
        EngineKind::HfStatic,
        EngineKind::Vllm,
        EngineKind::DistServe,
        EngineKind::BanaServe,
    ];
    let mut entries: Vec<(&str, Value)> = kinds
        .iter()
        .map(|&k| (k.name(), fingerprint(&fixed_cfg(k))))
        .collect();
    // the newly-elastic monolithic engines get their own golden entries
    entries.push(("vllm-elastic", fingerprint(&fixed_elastic_cfg(EngineKind::Vllm))));
    entries.push(("hft-elastic", fingerprint(&fixed_elastic_cfg(EngineKind::HfStatic))));
    let current = json::obj(entries);
    if !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, json::write(&current)).unwrap();
        eprintln!(
            "[behavior gate] golden snapshot seeded at {} — commit it; future \
             runs compare against it",
            path.display()
        );
        return;
    }
    let golden = json::parse(&std::fs::read_to_string(&path).unwrap())
        .expect("golden snapshot must parse");
    let names: Vec<&str> = kinds
        .iter()
        .map(|k| k.name())
        .chain(["vllm-elastic", "hft-elastic"])
        .collect();
    for name in names {
        let want = golden
            .get(name)
            .unwrap_or_else(|| panic!("golden snapshot missing engine {name}"));
        let got = current.get(name).unwrap();
        let obj = want.as_obj().expect("engine entry is an object");
        for (field, expect) in obj.iter() {
            let e = expect.as_f64().expect("golden fields are numeric");
            let g = got
                .get(field)
                .and_then(|v| v.as_f64())
                .unwrap_or_else(|| panic!("missing field {field} for {name}"));
            assert!(
                (e - g).abs() <= 1e-9 * e.abs().max(1.0),
                "{name} {field}: golden {e} != current {g} — the refactor changed \
                 behavior (delete the snapshot ONLY for an intentional change)"
            );
        }
    }
}

#[test]
fn golden_snapshot_covers_every_harness_entry() {
    // the snapshot (once seeded) must keep one fingerprint per harness
    // configuration — a refactor that silently drops an engine from the
    // gate would otherwise pass vacuously
    let path =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/engine_reports.json");
    if !path.exists() {
        eprintln!("[behavior gate] no golden snapshot yet — seeded by the gate test");
        return;
    }
    let golden = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    for name in ["hft", "vllm", "distserve", "banaserve", "vllm-elastic", "hft-elastic"] {
        assert!(
            golden.get(name).is_some(),
            "golden snapshot lost the '{name}' entry"
        );
    }
}

fn bursty_cfg(kind: EngineKind, devices: usize, elastic: bool, seed: u64) -> ExperimentConfig {
    let mut c = ExperimentConfig::default_for(kind, "llama-13b", 5.0, seed);
    c.n_devices = devices;
    c.n_prefill = (devices / 2).max(1);
    c.warmup = 0.0;
    c.workload = WorkloadConfig::poisson(LengthProfile::AlpacaShort, 5.0, 120.0, seed);
    c.workload.arrivals = ArrivalProcess::Bursty {
        rps: 5.0,
        burst_factor: 5.0,
        burst_secs: 12.0,
        period_secs: 48.0,
    };
    if elastic {
        c.autoscale.enabled = true;
        c.autoscale.min_devices = devices;
        c.autoscale.max_devices = 6;
    }
    c
}

#[test]
fn elastic_fleet_beats_static_base_p99_on_bursty_trace() {
    // The capability gate: same bursty trace, same base fleet of 2 devices;
    // the elastic run may scale to 6 during bursts. It must strictly beat
    // the static fleet's P99 total processing time.
    let stat = run_experiment(&bursty_cfg(EngineKind::BanaServe, 2, false, 11));
    let ela = run_experiment(&bursty_cfg(EngineKind::BanaServe, 2, true, 11));
    assert_eq!(
        stat.submitted,
        stat.report.n_requests + stat.report.dropped,
        "static run must account for every request"
    );
    assert_eq!(
        ela.submitted,
        ela.report.n_requests + ela.report.dropped,
        "elastic run must account for every request"
    );
    assert!(
        ela.extras.scale_outs > 0,
        "bursts must trigger scale-out (got {:?})",
        ela.extras.scale_outs
    );
    let mut rs = stat.report;
    let mut re = ela.report;
    let (p_stat, p_ela) = (rs.e2e.p99(), re.e2e.p99());
    assert!(
        p_ela < p_stat,
        "elastic P99 {p_ela:.2}s must beat static-base P99 {p_stat:.2}s"
    );
    // the fleet-size series must record the scaling trajectory
    let peak = ela
        .extras
        .fleet_size_series
        .iter()
        .map(|&(_, v)| v)
        .fold(0.0, f64::max);
    assert!(peak > 2.0, "fleet must have grown past its base size");
}

#[test]
fn distserve_elastic_fleet_runs_and_conserves() {
    let out = run_experiment(&bursty_cfg(EngineKind::DistServe, 2, true, 11));
    assert_eq!(out.submitted, out.report.n_requests + out.report.dropped);
    assert!(
        out.extras.scale_outs > 0,
        "bursty trace must trigger distserve scale-out"
    );
}

#[test]
fn autoscaler_drain_path_never_strands_requests() {
    // Aggressive scale-in thresholds force repeated drain/release cycles
    // between bursts; every admitted request must still complete
    // (run_experiment panics on conservation violations).
    for seed in [1, 2, 3] {
        let mut c = bursty_cfg(EngineKind::BanaServe, 3, true, seed);
        c.autoscale.min_devices = 2;
        c.autoscale.max_devices = 5;
        c.autoscale.scale_in_util = 0.9; // drain whenever not saturated
        c.autoscale.scale_out_util = 0.95;
        c.autoscale.cooldown = 1.0;
        c.bana.control_period = 0.5;
        c.workload.duration = 60.0;
        let out = run_experiment(&c);
        assert_eq!(
            out.submitted,
            out.report.n_requests + out.report.dropped,
            "seed {seed}: requests stranded by the drain path"
        );
    }
}

#[test]
fn slo_elastic_banaserve_meets_ttft_slo_at_lower_cost_than_static_peak() {
    // Self-calibrating target: the peak-provisioned static fleet defines
    // what an achievable TTFT looks like on this trace; the SLO is set to
    // 3x its P99 (floored at 2s — the scale-out ramp on the first burst
    // edge is physical, not a policy failure). The elastic fleet starts at
    // the trough size, scales on the windowed P99, and must (a) meet the
    // SLO and (b) pay less total device-cost than holding the peak fleet
    // for the whole run.
    let peak = run_experiment(&bursty_cfg(EngineKind::BanaServe, 6, false, 11));
    let mut rp = peak.report;
    let slo_s = (rp.ttft.p99() * 3.0).max(2.0);

    let mut c = bursty_cfg(EngineKind::BanaServe, 2, true, 11);
    c.autoscale.ttft_slo_ms = slo_s * 1e3;
    // ramp fast on breach, hold capacity while the SLO is anywhere near
    // the line — the cost win comes from the trough tails, not from
    // shaving devices mid-burst
    c.autoscale.cooldown = 2.0;
    c.autoscale.scale_in_util = 0.1;
    let ela = run_experiment(&c);
    assert_eq!(
        ela.submitted,
        ela.report.n_requests + ela.report.dropped,
        "elastic-SLO run must account for every request"
    );
    assert!(
        ela.extras.scale_outs > 0,
        "the SLO breach on the burst edge must trigger scale-out"
    );
    let mut re = ela.report;
    let p99_ttft = re.ttft.p99();
    assert!(
        p99_ttft <= slo_s,
        "elastic-SLO P99 TTFT {p99_ttft:.2}s must meet the {slo_s:.2}s SLO"
    );
    assert!(
        ela.extras.ttft_slo_attainment > 0.9,
        "attainment {:.2} should be high once the fleet tracks the SLO",
        ela.extras.ttft_slo_attainment
    );
    assert!(
        ela.extras.device_cost < peak.extras.device_cost,
        "elastic cost {:.1} must undercut static-peak cost {:.1}",
        ela.extras.device_cost,
        peak.extras.device_cost
    );
}

#[test]
fn elastic_vllm_scales_out_and_beats_static_base_p99() {
    let stat = run_experiment(&bursty_cfg(EngineKind::Vllm, 2, false, 11));
    let ela = run_experiment(&bursty_cfg(EngineKind::Vllm, 2, true, 11));
    assert_eq!(stat.submitted, stat.report.n_requests + stat.report.dropped);
    assert_eq!(ela.submitted, ela.report.n_requests + ela.report.dropped);
    assert!(
        ela.extras.scale_outs > 0,
        "bursts must trigger vllm scale-out"
    );
    let (mut rs, mut re) = (stat.report, ela.report);
    let (p_stat, p_ela) = (rs.e2e.p99(), re.e2e.p99());
    assert!(
        p_ela < p_stat,
        "elastic vllm P99 {p_ela:.2}s must beat static-base P99 {p_stat:.2}s"
    );
}

#[test]
fn elastic_hft_scales_out_and_conserves() {
    let out = run_experiment(&bursty_cfg(EngineKind::HfStatic, 2, true, 11));
    assert_eq!(out.submitted, out.report.n_requests + out.report.dropped);
    assert!(
        out.extras.scale_outs > 0,
        "bursty trace must trigger hft scale-out"
    );
}

#[test]
fn hetero_catalog_scale_out_records_mixed_specs_and_costs() {
    // deep-gap scale-outs under an aggressive SLO with a 40G/80G catalog:
    // the per-spec series and the cost accounting must both see the fleet
    let mut c = bursty_cfg(EngineKind::DistServe, 2, true, 11);
    c.gpu_catalog = vec![banaserve::cluster::A100_40G, banaserve::cluster::A100_80G];
    c.autoscale.ttft_slo_ms = 200.0; // tight: deep gaps early in each burst
    let out = run_experiment(&c);
    assert_eq!(out.submitted, out.report.n_requests + out.report.dropped);
    assert!(out.extras.scale_outs > 0, "tight SLO must force scale-outs");
    assert!(
        !out.extras.fleet_spec_series.is_empty(),
        "per-spec fleet series must be recorded"
    );
    assert!(
        out.extras.device_cost > 0.0,
        "elastic runs must report an integrated device cost"
    );
    assert!(
        !out.extras.fleet_cost_series.is_empty(),
        "cost-rate series must be recorded"
    );
}

#[test]
fn static_runs_are_deterministic_across_repeats() {
    // the golden gate relies on run-to-run determinism; make it explicit
    // for every configuration the snapshot pins — since the harness
    // refactor all six entries flow through the same generic
    // `run_experiment` path, so this also pins that path per engine
    let configs: Vec<ExperimentConfig> = [
        EngineKind::HfStatic,
        EngineKind::Vllm,
        EngineKind::DistServe,
        EngineKind::BanaServe,
    ]
    .iter()
    .map(|&k| fixed_cfg(k))
    .chain([
        fixed_elastic_cfg(EngineKind::Vllm),
        fixed_elastic_cfg(EngineKind::HfStatic),
    ])
    .collect();
    for cfg in &configs {
        let a = run_experiment(cfg);
        let b = run_experiment(cfg);
        assert_eq!(a.report.n_requests, b.report.n_requests);
        assert!(
            (a.report.throughput_tok_s - b.report.throughput_tok_s).abs() < 1e-9,
            "{:?} nondeterministic",
            cfg.engine
        );
        assert!((a.report.e2e.mean() - b.report.e2e.mean()).abs() < 1e-9);
        assert_eq!(a.extras.scale_outs, b.extras.scale_outs);
    }
}
