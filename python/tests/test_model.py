"""L2 model correctness: Pallas-backed model vs naive-attention oracle, and
the prefill/decode consistency contract the serving system depends on."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

SETTINGS = dict(max_examples=10, deadline=None)


@pytest.fixture(scope="module")
def tiny_weights():
    return M.init_weights(M.TINY)


class TestPrefill:
    def test_matches_naive_oracle(self, tiny_weights):
        toks = jnp.asarray(np.arange(1, 25) % M.TINY.vocab, jnp.int32)
        got, _, _ = M.prefill(tiny_weights, toks, M.TINY)
        want = M.prefill_ref(tiny_weights, toks, M.TINY)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
        )

    @given(st.integers(0, 2**31 - 1), st.integers(1, 32))
    @settings(**SETTINGS)
    def test_matches_oracle_random_prompts(self, seed, slen):
        rng = np.random.default_rng(seed)
        w = M.init_weights(M.TINY)
        toks = jnp.asarray(rng.integers(0, M.TINY.vocab, slen), jnp.int32)
        got, kc, vc = M.prefill(w, toks, M.TINY)
        want = M.prefill_ref(w, toks, M.TINY)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
        )
        assert kc.shape == (M.TINY.n_layers, M.TINY.n_kv_heads, slen, M.TINY.d_head)

    def test_prefix_property(self, tiny_weights):
        """prefill(prompt)[0:n] == prefill(prompt[:n]) — causality: later
        tokens never influence earlier logits. Incremental prefill and the
        Global KV Cache Store both rest on this."""
        toks = jnp.asarray(np.arange(3, 23) % M.TINY.vocab, jnp.int32)
        full, kc_full, vc_full = M.prefill(tiny_weights, toks, M.TINY)
        half, kc_half, vc_half = M.prefill(tiny_weights, toks[:10], M.TINY)
        np.testing.assert_allclose(
            np.asarray(full[:10]), np.asarray(half), rtol=1e-4, atol=1e-4
        )
        # the KV prefix is identical too -> cached prefixes are reusable
        np.testing.assert_allclose(
            np.asarray(kc_full[:, :, :10]), np.asarray(kc_half), rtol=1e-4, atol=1e-4
        )
        np.testing.assert_allclose(
            np.asarray(vc_full[:, :, :10]), np.asarray(vc_half), rtol=1e-4, atol=1e-4
        )

    def test_deterministic(self, tiny_weights):
        toks = jnp.asarray([5, 9, 1], jnp.int32)
        a, _, _ = M.prefill(tiny_weights, toks, M.TINY)
        b, _, _ = M.prefill(tiny_weights, toks, M.TINY)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestDecodeStep:
    def _padded_caches(self, cfg, kc, vc):
        maxs = cfg.max_seq
        s = kc.shape[2]
        kp = jnp.zeros((cfg.n_layers, cfg.n_kv_heads, maxs, cfg.d_head), jnp.float32)
        vp = jnp.zeros_like(kp)
        return kp.at[:, :, :s].set(kc), vp.at[:, :, :s].set(vc)

    @given(st.integers(0, 2**31 - 1))
    @settings(**SETTINGS)
    def test_decode_equals_prefill_extension(self, seed):
        """prefill(p + [t]) last logits == decode_step(t | prefill(p)) —
        THE autoregressive consistency contract."""
        cfg = M.TINY
        rng = np.random.default_rng(seed)
        w = M.init_weights(cfg)
        n = int(rng.integers(1, 30))
        toks = rng.integers(0, cfg.vocab, n + 1)
        full = M.prefill_ref(w, jnp.asarray(toks, jnp.int32), cfg)
        _, kc, vc = M.prefill(w, jnp.asarray(toks[:-1], jnp.int32), cfg)
        kp, vp = self._padded_caches(cfg, kc, vc)
        lg, _, _ = M.decode_step(
            w,
            jnp.asarray(toks[-1], jnp.int32),
            kp,
            vp,
            jnp.asarray(n, jnp.int32),
            cfg,
        )
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full[-1]), rtol=2e-4, atol=2e-4
        )

    def test_multistep_greedy_matches_full_prefill(self, tiny_weights):
        """Greedy-decode 6 tokens stepwise; re-prefilling the whole sequence
        must predict the same continuation at each position."""
        cfg = M.TINY
        w = tiny_weights
        prompt = list(np.arange(2, 12))
        _, kc, vc = M.prefill(w, jnp.asarray(prompt, jnp.int32), cfg)
        kp, vp = self._padded_caches(cfg, kc, vc)
        seq = list(prompt)
        logits, _, _ = M.prefill(w, jnp.asarray(seq, jnp.int32), cfg)
        cur = int(np.asarray(logits[-1]).argmax())
        for step in range(6):
            lg, kp, vp = M.decode_step(
                w,
                jnp.asarray(cur, jnp.int32),
                kp,
                vp,
                jnp.asarray(len(seq), jnp.int32),
                cfg,
            )
            seq.append(cur)
            ref_logits = M.prefill_ref(w, jnp.asarray(seq, jnp.int32), cfg)
            np.testing.assert_allclose(
                np.asarray(lg), np.asarray(ref_logits[-1]), rtol=5e-4, atol=5e-4
            )
            cur = int(np.asarray(lg).argmax())

    def test_cache_garbage_beyond_len_ignored(self, tiny_weights):
        cfg = M.TINY
        prompt = jnp.asarray([1, 2, 3, 4, 5], jnp.int32)
        _, kc, vc = M.prefill(tiny_weights, prompt, cfg)
        kp, vp = self._padded_caches(cfg, kc, vc)
        kp2 = kp.at[:, :, 10:].set(123.0)
        vp2 = vp.at[:, :, 10:].set(-321.0)
        l1, _, _ = M.decode_step(
            tiny_weights, jnp.asarray(7, jnp.int32), kp, vp,
            jnp.asarray(5, jnp.int32), cfg,
        )
        l2, _, _ = M.decode_step(
            tiny_weights, jnp.asarray(7, jnp.int32), kp2, vp2,
            jnp.asarray(5, jnp.int32), cfg,
        )
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2))


class TestBatchedEntryPoints:
    def test_batched_prefill_rows_independent(self):
        cfg = M.TINY
        fn, _ = M.make_prefill_fn(cfg, batch=4, seq=8)
        rng = np.random.default_rng(3)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 8)), jnp.int32)
        logits, kc, vc = fn(toks)
        assert logits.shape == (4, 8, cfg.vocab)
        # each row equals the unbatched run
        w = M.init_weights(cfg)
        for b in range(4):
            want, _, _ = M.prefill(w, toks[b], cfg)
            np.testing.assert_allclose(
                np.asarray(logits[b]), np.asarray(want), rtol=1e-4, atol=1e-4
            )

    def test_batched_decode_rows_independent(self):
        cfg = M.TINY
        dfn, _ = M.make_decode_fn(cfg, batch=2)
        w = M.init_weights(cfg)
        rng = np.random.default_rng(4)
        maxs = cfg.max_seq
        prompts = [rng.integers(0, cfg.vocab, 6), rng.integers(0, cfg.vocab, 11)]
        kps, vps, lens = [], [], []
        for p in prompts:
            _, kc, vc = M.prefill(w, jnp.asarray(p, jnp.int32), cfg)
            kp = jnp.zeros((cfg.n_layers, cfg.n_kv_heads, maxs, cfg.d_head))
            vp = jnp.zeros_like(kp)
            kps.append(kp.at[:, :, : len(p)].set(kc))
            vps.append(vp.at[:, :, : len(p)].set(vc))
            lens.append(len(p))
        toks = jnp.asarray([9, 13], jnp.int32)
        lg, _, _ = dfn(
            toks,
            jnp.stack(kps),
            jnp.stack(vps),
            jnp.asarray(lens, jnp.int32),
        )
        for b in range(2):
            want, _, _ = M.decode_step(
                w, toks[b], kps[b], vps[b], jnp.asarray(lens[b], jnp.int32), cfg
            )
            np.testing.assert_allclose(
                np.asarray(lg[b]), np.asarray(want), rtol=1e-4, atol=1e-4
            )


class TestConfig:
    def test_param_count_tiny(self):
        cfg = M.TINY
        w = M.init_weights(cfg)
        total = w["embed"].size + w["final_norm"].size + w["lm_head"].size
        for layer in w["layers"]:
            total += sum(np.asarray(p).size for p in layer.values())
        assert total == cfg.param_count()

    def test_d_head_divides(self):
        for cfg in (M.TINY, M.SMALL):
            assert cfg.d_model == cfg.n_heads * cfg.d_head
            assert cfg.n_heads % cfg.n_kv_heads == 0
