"""L1 correctness: Pallas attention kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes / GQA ratios / splits / dtypes; every property here
is a contract the rust coordinator relies on (the migration math must be
exact, or attention-level migration would corrupt outputs).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import (
    attention_partial,
    decode_attention,
    flash_attention,
    merge_partials,
    split_attention,
)

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=25, deadline=None)


def rand(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


def assert_close(a, b, dtype=jnp.float32):
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32), atol=tol, rtol=tol
    )


@st.composite
def attn_shapes(draw):
    d = draw(st.sampled_from([8, 16, 32]))
    hkv = draw(st.sampled_from([1, 2, 4]))
    rep = draw(st.sampled_from([1, 2, 4]))
    sq = draw(st.integers(1, 48))
    sk = draw(st.integers(1, 64))
    seed = draw(st.integers(0, 2**31 - 1))
    return d, hkv, rep, sq, sk, seed


class TestFlashAttention:
    @given(attn_shapes())
    @settings(**SETTINGS)
    def test_matches_ref_causal_square(self, shp):
        d, hkv, rep, sq, _, seed = shp
        rng = np.random.default_rng(seed)
        h = hkv * rep
        q = rand(rng, (h, sq, d))
        k = rand(rng, (hkv, sq, d))
        v = rand(rng, (hkv, sq, d))
        out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
        assert_close(out, ref.attention_ref(q, k, v, causal=True))

    @given(attn_shapes())
    @settings(**SETTINGS)
    def test_matches_ref_noncausal_rect(self, shp):
        d, hkv, rep, sq, sk, seed = shp
        rng = np.random.default_rng(seed)
        h = hkv * rep
        q = rand(rng, (h, sq, d))
        k = rand(rng, (hkv, sk, d))
        v = rand(rng, (hkv, sk, d))
        out = flash_attention(q, k, v, causal=False, block_q=16, block_k=16)
        assert_close(out, ref.attention_ref(q, k, v, causal=False))

    @given(st.integers(0, 2**31 - 1), st.integers(1, 30))
    @settings(**SETTINGS)
    def test_q_offset_chunked_prefill(self, seed, off):
        """Chunked prefill: later q chunk with q_offset equals the suffix of
        full causal attention — the contract incremental prefill relies on."""
        rng = np.random.default_rng(seed)
        h, hkv, d = 4, 2, 16
        sk = off + 9
        q_full = rand(rng, (h, sk, d))
        k = rand(rng, (hkv, sk, d))
        v = rand(rng, (hkv, sk, d))
        full = ref.attention_ref(q_full, k, v, causal=True)
        tail = flash_attention(
            q_full[:, off:, :], k, v, causal=True, q_offset=off, block_q=8, block_k=8
        )
        assert_close(tail, full[:, off:, :])

    def test_bf16_io(self):
        rng = np.random.default_rng(0)
        h, hkv, s, d = 4, 2, 24, 16
        q = rand(rng, (h, s, d), jnp.bfloat16)
        k = rand(rng, (hkv, s, d), jnp.bfloat16)
        v = rand(rng, (hkv, s, d), jnp.bfloat16)
        out = flash_attention(q, k, v, causal=True, block_q=8, block_k=8)
        assert out.dtype == jnp.bfloat16
        assert_close(out, ref.attention_ref(q, k, v, causal=True), jnp.bfloat16)

    def test_block_size_invariance(self):
        rng = np.random.default_rng(1)
        h, hkv, s, d = 4, 4, 40, 16
        q = rand(rng, (h, s, d))
        k = rand(rng, (hkv, s, d))
        v = rand(rng, (hkv, s, d))
        outs = [
            flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
            for bq, bk in [(8, 8), (16, 32), (64, 16), (128, 128)]
        ]
        for o in outs[1:]:
            assert_close(o, outs[0])

    def test_single_token(self):
        rng = np.random.default_rng(2)
        q = rand(rng, (2, 1, 8))
        k = rand(rng, (2, 1, 8))
        v = rand(rng, (2, 1, 8))
        out = flash_attention(q, k, v, causal=True)
        # single key -> output == v
        assert_close(out, ref.repeat_kv(v, 1))


class TestSplitMigrationMath:
    """The paper's Eqs 6-10: disjoint partitions + merge == full attention."""

    @given(attn_shapes(), st.floats(0.05, 0.95))
    @settings(**SETTINGS)
    def test_split_equals_full(self, shp, frac):
        d, hkv, rep, sq, sk, seed = shp
        if sk < 2:
            sk = 2
        rng = np.random.default_rng(seed)
        h = hkv * rep
        split = min(max(int(sk * frac), 1), sk - 1)
        q = rand(rng, (h, sq, d))
        k = rand(rng, (hkv, sk, d))
        v = rand(rng, (hkv, sk, d))
        got = split_attention(q, k, v, split, causal=False)
        assert_close(got, ref.attention_ref(q, k, v, causal=False))

    @given(st.integers(0, 2**31 - 1), st.integers(2, 40))
    @settings(**SETTINGS)
    def test_split_equals_full_causal(self, seed, sk):
        rng = np.random.default_rng(seed)
        h, hkv, d = 4, 2, 16
        split = sk // 2
        q = rand(rng, (h, sk, d))
        k = rand(rng, (hkv, sk, d))
        v = rand(rng, (hkv, sk, d))
        got = split_attention(q, k, v, split, causal=True)
        assert_close(got, ref.attention_ref(q, k, v, causal=True))

    @given(st.integers(0, 2**31 - 1))
    @settings(**SETTINGS)
    def test_partial_matches_ref_partial(self, seed):
        rng = np.random.default_rng(seed)
        h, hkv, sq, sk, d = 4, 2, 12, 20, 16
        q = rand(rng, (h, sq, d))
        k = rand(rng, (hkv, sk, d))
        v = rand(rng, (hkv, sk, d))
        o, m, l = attention_partial(q, k, v, causal=False, block_q=8, block_k=8)
        o_r, m_r, l_r = ref.attention_partial_ref(q, k, v, causal=False)
        # partials are defined up to the shared max; compare normalized forms
        got = np.asarray(o) * np.exp(np.asarray(m))[:, :, None]
        want = np.asarray(o_r) * np.exp(np.asarray(m_r))[:, :, None]
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(l) * np.exp(np.asarray(m)),
            np.asarray(l_r) * np.exp(np.asarray(m_r)),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_merge_is_associative_three_way(self):
        """Merging ((P1,P2),P3) == merging ((P1,P3),P2) — ordering freedom
        the coordinator uses when cold-device results arrive out of order."""
        rng = np.random.default_rng(7)
        h, hkv, sq, sk, d = 2, 2, 6, 30, 8
        q = rand(rng, (h, sq, d))
        k = rand(rng, (hkv, sk, d))
        v = rand(rng, (hkv, sk, d))
        parts = [
            ref.attention_partial_ref(
                q, k[:, a:b], v[:, a:b], kpos_offset=a, causal=False
            )
            for a, b in [(0, 10), (10, 20), (20, 30)]
        ]
        m012 = ref.merge_partials_ref([parts[0], parts[1], parts[2]])
        m021 = ref.merge_partials_ref([parts[0], parts[2], parts[1]])
        m210 = ref.merge_partials_ref([parts[2], parts[1], parts[0]])
        assert_close(m012, m021)
        assert_close(m012, m210)
        assert_close(m012, ref.attention_ref(q, k, v, causal=False))

    def test_merge_kernel_matches_ref_merge(self):
        rng = np.random.default_rng(8)
        h, hkv, sq, sk, d = 4, 2, 8, 24, 16
        q = rand(rng, (h, sq, d))
        k = rand(rng, (hkv, sk, d))
        v = rand(rng, (hkv, sk, d))
        p1 = attention_partial(q, k[:, :12], v[:, :12], causal=False)
        p2 = attention_partial(
            q, k[:, 12:], v[:, 12:], kpos_offset=12, causal=False
        )
        got = merge_partials(p1, p2)
        want = ref.merge_partials_ref(
            [
                ref.attention_partial_ref(q, k[:, :12], v[:, :12], causal=False),
                ref.attention_partial_ref(
                    q, k[:, 12:], v[:, 12:], kpos_offset=12, causal=False
                ),
            ]
        )
        assert_close(got, want)

    def test_extreme_magnitudes_stable(self):
        """Online-softmax merge must survive large score disparities."""
        h, hkv, sq, d = 2, 2, 4, 8
        rng = np.random.default_rng(9)
        q = rand(rng, (h, sq, d)) * 10.0
        k = jnp.concatenate([rand(rng, (hkv, 8, d)) * 10.0, rand(rng, (hkv, 8, d)) * 0.01], axis=1)
        v = rand(rng, (hkv, 16, d))
        got = split_attention(q, k, v, 8, causal=False)
        want = ref.attention_ref(q, k, v, causal=False)
        assert_close(got, want)
        assert np.isfinite(np.asarray(got)).all()

    @given(st.integers(0, 2**31 - 1))
    @settings(**SETTINGS)
    def test_head_split_equals_full(self, seed):
        rng = np.random.default_rng(seed)
        h, hkv, s, d = 8, 4, 10, 16
        q = rand(rng, (h, s, d))
        k = rand(rng, (hkv, s, d))
        v = rand(rng, (hkv, s, d))
        got = ref.head_split_attention_ref(q, k, v, head_split=4, causal=True)
        assert_close(got, ref.attention_ref(q, k, v, causal=True))


class TestDecodeAttention:
    @given(
        st.integers(0, 2**31 - 1),
        st.integers(1, 60),
        st.sampled_from([(4, 2), (8, 8), (2, 1)]),
    )
    @settings(**SETTINGS)
    def test_matches_ref(self, seed, kvlen, heads):
        h, hkv = heads
        d, smax = 16, 64
        rng = np.random.default_rng(seed)
        q = rand(rng, (h, d))
        k = rand(rng, (hkv, smax, d))
        v = rand(rng, (hkv, smax, d))
        got = decode_attention(q, k, v, kvlen, block_k=16)
        want = ref.attention_ref(q[:, None, :], k, v, causal=False, kv_len=kvlen)[:, 0]
        assert_close(got, want)

    def test_padding_is_ignored(self):
        """Garbage beyond kv_len must not change the result."""
        rng = np.random.default_rng(3)
        h, hkv, smax, d = 4, 2, 32, 16
        q = rand(rng, (h, d))
        k = rand(rng, (hkv, smax, d))
        v = rand(rng, (hkv, smax, d))
        kvlen = 11
        out1 = decode_attention(q, k, v, kvlen)
        k2 = k.at[:, kvlen:, :].set(1e6)
        v2 = v.at[:, kvlen:, :].set(-1e6)
        out2 = decode_attention(q, k2, v2, kvlen)
        assert_close(out1, out2)

    def test_kvlen_one(self):
        rng = np.random.default_rng(4)
        h, hkv, smax, d = 2, 2, 16, 8
        q = rand(rng, (h, d))
        k = rand(rng, (hkv, smax, d))
        v = rand(rng, (hkv, smax, d))
        out = decode_attention(q, k, v, 1)
        assert_close(out, v[:, 0, :])


class TestScaleAndMask:
    def test_custom_scale(self):
        rng = np.random.default_rng(5)
        h, hkv, s, d = 2, 2, 8, 16
        q = rand(rng, (h, s, d))
        k = rand(rng, (hkv, s, d))
        v = rand(rng, (hkv, s, d))
        out = flash_attention(q, k, v, causal=False, scale=0.5)
        want = ref.attention_ref(q, k, v, causal=False, scale=0.5)
        assert_close(out, want)

    def test_first_row_causal_is_v0(self):
        rng = np.random.default_rng(6)
        h, hkv, s, d = 2, 1, 12, 8
        q = rand(rng, (h, s, d))
        k = rand(rng, (hkv, s, d))
        v = rand(rng, (hkv, s, d))
        out = flash_attention(q, k, v, causal=True)
        for hh in range(h):
            np.testing.assert_allclose(
                np.asarray(out[hh, 0]), np.asarray(v[0, 0]), rtol=1e-5, atol=1e-5
            )
