"""AOT pipeline: HLO text is produced, looks like HLO, and the manifest /
golden files agree with a fresh in-process computation."""

import json
import os

import numpy as np
import jax.numpy as jnp

from compile import aot, model as M

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lower_prefill_produces_hlo_text():
    lowered, specs = aot.lower_entry(M.TINY, "prefill", 1, 8)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # weights are baked: no parameter beyond the token input
    assert len(specs) == 1


def test_lower_decode_produces_hlo_text():
    lowered, specs = aot.lower_entry(M.TINY, "decode", 1, None)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert len(specs) == 4


def test_entry_names():
    assert aot.entry_name("tiny", "prefill", 4, 32) == "tiny.prefill.b4s32"
    assert aot.entry_name("tiny", "decode", 1, None) == "tiny.decode.b1"


def test_manifest_and_artifacts_exist():
    """make artifacts must have run (it is a prerequisite of `make test`)."""
    man_path = os.path.join(ARTIFACTS, "manifest.json")
    assert os.path.exists(man_path), "run `make artifacts` first"
    with open(man_path) as f:
        man = json.load(f)
    assert man["format"] == "hlo-text"
    tiny = man["variants"]["tiny"]
    assert tiny["config"]["vocab"] == M.TINY.vocab
    for name, entry in tiny["entries"].items():
        p = os.path.join(ARTIFACTS, entry["file"])
        assert os.path.exists(p), p
        with open(p) as f:
            head = f.read(64)
        assert head.startswith("HloModule")


def test_golden_matches_fresh_computation():
    gpath = os.path.join(ARTIFACTS, "tiny.golden.json")
    assert os.path.exists(gpath), "run `make artifacts` first"
    with open(gpath) as f:
        golden = json.load(f)
    fresh = aot.golden_outputs(M.TINY)
    assert golden["prompt"] == fresh["prompt"]
    assert golden["generated"] == fresh["generated"]
    np.testing.assert_allclose(
        golden["prefill_logits_first4"],
        fresh["prefill_logits_first4"],
        rtol=1e-5,
    )


def test_golden_decode_fingerprints_are_finite():
    fresh = aot.golden_outputs(M.TINY)
    for fp in fresh["fingerprints"]:
        assert np.isfinite(fp["sum"])
        assert all(np.isfinite(x) for x in fp["first4"])


def test_hlo_text_has_no_elided_constants():
    """Guard against the elided-constant trap: the default HLO printer
    writes big literals as ``constant({...})`` and the runtime's XLA text
    parser silently reads them as ZEROS. aot.py must always print full
    constants (this bug zeroed every baked weight once)."""
    lowered, _ = aot.lower_entry(M.TINY, "prefill", 1, 8)
    text = aot.to_hlo_text(lowered)
    assert "{...}" not in text, "elided constants in HLO text"
    # built artifacts must be clean too
    for name in os.listdir(ARTIFACTS):
        if name.endswith(".hlo.txt"):
            with open(os.path.join(ARTIFACTS, name)) as f:
                assert "{...}" not in f.read(), f"elided constants in {name}"
