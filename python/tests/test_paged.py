"""Paged (block-table) attention kernel vs reference."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.paged import paged_attention

SETTINGS = dict(max_examples=25, deadline=None)


def rand(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


@st.composite
def paged_case(draw):
    d = draw(st.sampled_from([8, 16]))
    hkv = draw(st.sampled_from([1, 2, 4]))
    rep = draw(st.sampled_from([1, 2]))
    bsz = draw(st.sampled_from([4, 8, 16]))
    pool = draw(st.integers(4, 24))
    nblocks = draw(st.integers(1, min(pool, 6)))
    seed = draw(st.integers(0, 2**31 - 1))
    ctx = draw(st.integers(1, nblocks * bsz))
    return d, hkv, rep, bsz, pool, nblocks, ctx, seed


@given(paged_case())
@settings(**SETTINGS)
def test_paged_matches_ref(case):
    d, hkv, rep, bsz, pool, nblocks, ctx, seed = case
    rng = np.random.default_rng(seed)
    h = hkv * rep
    q = rand(rng, (h, d))
    kp = rand(rng, (pool, hkv, bsz, d))
    vp = rand(rng, (pool, hkv, bsz, d))
    bt = jnp.asarray(rng.choice(pool, size=nblocks, replace=False), jnp.int32)
    got = paged_attention(q, kp, vp, bt, ctx)
    want = ref.paged_attention_ref(q, kp, vp, bt, ctx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_paged_equals_contiguous():
    """A block table that happens to be contiguous must equal plain decode
    attention over the same contiguous cache — paging is memory layout only."""
    rng = np.random.default_rng(11)
    h, hkv, bsz, d = 4, 2, 8, 16
    n = 4
    kp = rand(rng, (n, hkv, bsz, d))
    vp = rand(rng, (n, hkv, bsz, d))
    q = rand(rng, (h, d))
    bt = jnp.arange(n, dtype=jnp.int32)
    ctx = 27
    got = paged_attention(q, kp, vp, bt, ctx)
    k = jnp.transpose(kp, (1, 0, 2, 3)).reshape(hkv, n * bsz, d)
    v = jnp.transpose(vp, (1, 0, 2, 3)).reshape(hkv, n * bsz, d)
    want = ref.attention_ref(q[:, None, :], k, v, causal=False, kv_len=ctx)[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_permuted_pool_pages_unused_are_ignored():
    """Pages not referenced in the block table must never affect output."""
    rng = np.random.default_rng(12)
    h, hkv, bsz, d, pool = 2, 2, 4, 8, 10
    q = rand(rng, (h, d))
    kp = rand(rng, (pool, hkv, bsz, d))
    vp = rand(rng, (pool, hkv, bsz, d))
    bt = jnp.asarray([2, 5, 7], jnp.int32)
    ctx = 12
    out1 = paged_attention(q, kp, vp, bt, ctx)
    # trash every page NOT in the table
    mask = np.ones(pool, bool)
    mask[[2, 5, 7]] = False
    kp2 = kp.at[np.where(mask)[0]].set(1e9)
    vp2 = vp.at[np.where(mask)[0]].set(-1e9)
    out2 = paged_attention(q, kp2, vp2, bt, ctx)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))


def test_partial_last_block_masked():
    rng = np.random.default_rng(13)
    h, hkv, bsz, d, pool = 2, 1, 8, 8, 4
    q = rand(rng, (h, d))
    kp = rand(rng, (pool, hkv, bsz, d))
    vp = rand(rng, (pool, hkv, bsz, d))
    bt = jnp.asarray([0, 1], jnp.int32)
    ctx = 9  # one token into the second block
    out1 = paged_attention(q, kp, vp, bt, ctx)
    kp2 = kp.at[1, :, 1:, :].set(1e9)  # garbage beyond ctx within block 1
    vp2 = vp.at[1, :, 1:, :].set(-1e9)
    out2 = paged_attention(q, kp2, vp2, bt, ctx)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))
