"""AOT compile path: lower the L2 model entry points to HLO *text* and write
them, plus a manifest and golden outputs, into ``artifacts/``.

Interchange format is HLO text, NOT serialized HloModuleProto: the image's
xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit instruction ids fail the
``proto.id() <= INT_MAX`` check); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Run once at build time (`make artifacts`); the rust binary is self-contained
afterwards.

Usage: cd python && python -m compile.aot --out-dir ../artifacts [--variants tiny]
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# Entry points to AOT per model variant: (kind, batch, seq-or-None)
DEFAULT_ENTRIES = [
    ("prefill", 1, 32),
    ("prefill", 4, 32),
    ("decode", 1, None),
    ("decode", 4, None),
]

VARIANTS = {"tiny": M.TINY, "small": M.SMALL}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the parser).

    ``print_large_constants=True`` is ESSENTIAL: the default printer elides
    big literals as ``constant({...})``, which the xla_extension 0.5.1 text
    parser silently reads as zeros — every baked weight would vanish.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def entry_name(variant: str, kind: str, batch: int, seq) -> str:
    if kind == "prefill":
        return f"{variant}.prefill.b{batch}s{seq}"
    return f"{variant}.decode.b{batch}"


def lower_entry(cfg: M.ModelConfig, kind: str, batch: int, seq):
    if kind == "prefill":
        fn, specs = M.make_prefill_fn(cfg, batch, seq)
    else:
        fn, specs = M.make_decode_fn(cfg, batch)
    return jax.jit(fn).lower(*specs), specs


def spec_json(spec) -> dict:
    return {"shape": list(spec.shape), "dtype": str(spec.dtype)}


def golden_outputs(cfg: M.ModelConfig) -> dict:
    """Golden numbers for the rust integration tests.

    A fixed 16-token prompt through prefill + 4 greedy decode steps; store
    prompt, argmax tokens, and logit fingerprints (first 4 values + sum).
    """
    w = M.init_weights(cfg)
    prompt = [(7 * i + 3) % cfg.vocab for i in range(16)]
    toks = jnp.asarray(prompt, jnp.int32)
    logits, kc, vc = M.prefill(w, toks, cfg)

    maxs = cfg.max_seq
    kpad = jnp.zeros((cfg.n_layers, cfg.n_kv_heads, maxs, cfg.d_head), jnp.float32)
    vpad = jnp.zeros_like(kpad)
    kpad = kpad.at[:, :, : len(prompt), :].set(kc)
    vpad = vpad.at[:, :, : len(prompt), :].set(vc)

    last = logits[-1]
    fingerprints = [
        {
            "first4": [float(x) for x in np.asarray(last[:4])],
            "sum": float(np.asarray(last).sum()),
        }
    ]
    gen = []
    cur = int(np.asarray(last).argmax())
    cur_len = len(prompt)
    for _ in range(4):
        gen.append(cur)
        lg, kpad, vpad = M.decode_step(
            w,
            jnp.asarray(cur, jnp.int32),
            kpad,
            vpad,
            jnp.asarray(cur_len, jnp.int32),
            cfg,
        )
        fingerprints.append(
            {
                "first4": [float(x) for x in np.asarray(lg[:4])],
                "sum": float(np.asarray(lg).sum()),
            }
        )
        cur = int(np.asarray(lg).argmax())
        cur_len += 1

    return {
        "prompt": prompt,
        "generated": gen,
        "prefill_logits_first4": [float(x) for x in np.asarray(logits[-1][:4])],
        "fingerprints": fingerprints,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--variants", default="tiny", help="comma list from: " + ",".join(VARIANTS)
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "variants": {}}
    for vname in args.variants.split(","):
        cfg = VARIANTS[vname]
        ventry = {
            "config": {
                "vocab": cfg.vocab,
                "d_model": cfg.d_model,
                "n_layers": cfg.n_layers,
                "n_heads": cfg.n_heads,
                "n_kv_heads": cfg.n_kv_heads,
                "d_head": cfg.d_head,
                "d_ff": cfg.d_ff,
                "max_seq": cfg.max_seq,
                "param_count": cfg.param_count(),
                "seed": cfg.seed,
            },
            "entries": {},
        }
        for kind, batch, seq in DEFAULT_ENTRIES:
            name = entry_name(vname, kind, batch, seq)
            lowered, specs = lower_entry(cfg, kind, batch, seq)
            text = to_hlo_text(lowered)
            path = os.path.join(args.out_dir, name + ".hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            ventry["entries"][name] = {
                "kind": kind,
                "batch": batch,
                "seq": seq,
                "file": name + ".hlo.txt",
                "inputs": [spec_json(s) for s in specs],
            }
            print(f"wrote {path} ({len(text)} chars)")
        manifest["variants"][vname] = ventry

        golden = golden_outputs(cfg)
        gpath = os.path.join(args.out_dir, f"{vname}.golden.json")
        with open(gpath, "w") as f:
            json.dump(golden, f, indent=1)
        print(f"wrote {gpath}")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("wrote manifest.json")


if __name__ == "__main__":
    main()
