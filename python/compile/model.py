"""L2: decoder-only transformer (JAX) whose attention runs through the L1
Pallas kernels. Build-time only — ``aot.py`` lowers `prefill` / `decode_step`
to HLO text; the rust runtime executes the artifacts. Python is never on the
request path.

Architecture (LLaMA-flavoured, matching the paper's models in miniature):
RMSNorm -> GQA attention with RoPE -> RMSNorm -> SwiGLU MLP, residual
connections, tied or untied LM head. Weights are generated from a fixed seed
at trace time and baked into the HLO as constants, so the rust binary is
fully self-contained after `make artifacts`.

Entry points (all functional, B=1 per call; batching is vmap'd in aot.py):
  prefill(tokens[S])                        -> logits[S,V], k[L,Hkv,S,D], v[L,Hkv,S,D]
  decode_step(token[1], k, v, cur_len)      -> logits[V], k', v'   (padded caches [L,Hkv,MAX,D])
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp

from .kernels.attention import decode_attention, flash_attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture description of an AOT model variant."""

    name: str = "tiny"
    vocab: int = 256
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 4
    n_kv_heads: int = 2
    d_ff: int = 128
    max_seq: int = 128
    rope_theta: float = 10000.0
    seed: int = 42

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, dh = self.d_model, self.d_head
        per_layer = (
            d * (self.n_heads * dh)          # wq
            + 2 * d * (self.n_kv_heads * dh)  # wk, wv
            + (self.n_heads * dh) * d         # wo
            + 3 * d * self.d_ff               # gate, up, down
            + 2 * d                           # norms
        )
        return self.vocab * d * 2 + d + self.n_layers * per_layer


TINY = ModelConfig()
SMALL = ModelConfig(
    name="small", d_model=128, n_layers=4, n_heads=8, n_kv_heads=4, d_ff=256
)


def init_weights(cfg: ModelConfig):
    """Deterministic weights from cfg.seed (numpy, so trace-time constants)."""
    rng = np.random.default_rng(cfg.seed)
    d, dh = cfg.d_model, cfg.d_head

    def mat(shape, fan_in):
        return jnp.asarray(
            rng.standard_normal(shape) * (1.0 / np.sqrt(fan_in)), jnp.float32
        )

    layers = []
    for _ in range(cfg.n_layers):
        layers.append(
            {
                "attn_norm": jnp.ones((d,), jnp.float32),
                "wq": mat((d, cfg.n_heads * dh), d),
                "wk": mat((d, cfg.n_kv_heads * dh), d),
                "wv": mat((d, cfg.n_kv_heads * dh), d),
                "wo": mat((cfg.n_heads * dh, d), cfg.n_heads * dh),
                "mlp_norm": jnp.ones((d,), jnp.float32),
                "w_gate": mat((d, cfg.d_ff), d),
                "w_up": mat((d, cfg.d_ff), d),
                "w_down": mat((cfg.d_ff, d), cfg.d_ff),
            }
        )
    return {
        "embed": mat((cfg.vocab, d), d),
        "final_norm": jnp.ones((d,), jnp.float32),
        "lm_head": mat((d, cfg.vocab), d),
        "layers": layers,
    }


def rmsnorm(x, w, eps: float = 1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope_freqs(cfg: ModelConfig):
    dh = cfg.d_head
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    return inv  # [dh/2]


def apply_rope(x, positions, cfg: ModelConfig):
    """x [H,S,D] (D even), positions [S] int32 -> rotated x."""
    inv = rope_freqs(cfg)  # [D/2]
    ang = positions.astype(jnp.float32)[:, None] * inv[None, :]  # [S, D/2]
    cos = jnp.cos(ang)[None, :, :]
    sin = jnp.sin(ang)[None, :, :]
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out


def _project_qkv(layer, x, cfg: ModelConfig):
    """x [S,d] -> q [H,S,Dh], k,v [Hkv,S,Dh]."""
    s = x.shape[0]
    dh = cfg.d_head
    q = (x @ layer["wq"]).reshape(s, cfg.n_heads, dh).transpose(1, 0, 2)
    k = (x @ layer["wk"]).reshape(s, cfg.n_kv_heads, dh).transpose(1, 0, 2)
    v = (x @ layer["wv"]).reshape(s, cfg.n_kv_heads, dh).transpose(1, 0, 2)
    return q, k, v


def _mlp(layer, x):
    g = x @ layer["w_gate"]
    u = x @ layer["w_up"]
    return (g * jax.nn.sigmoid(g) * u) @ layer["w_down"]


def prefill(weights, tokens, cfg: ModelConfig):
    """Process a full prompt. tokens [S] int32.

    Returns (logits [S,V], k_cache [L,Hkv,S,Dh], v_cache [L,Hkv,S,Dh]).
    Attention goes through the Pallas flash kernel.
    """
    s = tokens.shape[0]
    pos = jnp.arange(s, dtype=jnp.int32)
    x = weights["embed"][tokens]  # [S, d]

    ks, vs = [], []
    for layer in weights["layers"]:
        h = rmsnorm(x, layer["attn_norm"])
        q, k, v = _project_qkv(layer, h, cfg)
        q = apply_rope(q, pos, cfg)
        k = apply_rope(k, pos, cfg)
        o = flash_attention(q, k, v, causal=True)  # [H,S,Dh]
        o = o.transpose(1, 0, 2).reshape(s, cfg.n_heads * cfg.d_head)
        x = x + o @ layer["wo"]
        h = rmsnorm(x, layer["mlp_norm"])
        x = x + _mlp(layer, h)
        ks.append(k)
        vs.append(v)

    x = rmsnorm(x, weights["final_norm"])
    logits = x @ weights["lm_head"]
    return logits, jnp.stack(ks), jnp.stack(vs)


def decode_step(weights, token, k_cache, v_cache, cur_len, cfg: ModelConfig):
    """One auto-regressive step against padded caches.

    token    [] int32          the token produced at position cur_len-? — the
                               *input* token whose successor we predict
    k_cache  [L,Hkv,MAX,Dh]    padded; positions >= cur_len are garbage
    v_cache  [L,Hkv,MAX,Dh]
    cur_len  [] int32          valid cache length BEFORE this step

    Returns (logits [V], k_cache', v_cache') with the new KV written at
    position cur_len. Attention uses the Pallas decode kernel with the
    dynamic length mask (cur_len + 1 after the write).
    """
    pos = cur_len
    x = weights["embed"][token]  # [d]

    new_k = k_cache
    new_v = v_cache
    for li, layer in enumerate(weights["layers"]):
        h = rmsnorm(x, layer["attn_norm"])
        dh = cfg.d_head
        q = (h @ layer["wq"]).reshape(cfg.n_heads, 1, dh)
        k = (h @ layer["wk"]).reshape(cfg.n_kv_heads, 1, dh)
        v = (h @ layer["wv"]).reshape(cfg.n_kv_heads, 1, dh)
        posv = pos.reshape((1,))
        q = apply_rope(q, posv, cfg)
        k = apply_rope(k, posv, cfg)

        # write k/v at position cur_len
        kc = jax.lax.dynamic_update_slice(
            new_k[li], k.transpose(0, 1, 2), (0, pos, 0)
        )
        vc = jax.lax.dynamic_update_slice(new_v[li], v, (0, pos, 0))
        new_k = new_k.at[li].set(kc)
        new_v = new_v.at[li].set(vc)

        o = decode_attention(q[:, 0, :], kc, vc, pos + 1)  # [H,Dh]
        o = o.reshape(cfg.n_heads * dh)
        x = x + o @ layer["wo"]
        h = rmsnorm(x, layer["mlp_norm"])
        x = x + _mlp(layer, h)

    x = rmsnorm(x, weights["final_norm"])
    logits = x @ weights["lm_head"]
    return logits, new_k, new_v


def prefill_ref(weights, tokens, cfg: ModelConfig):
    """Reference prefill using naive attention (no Pallas) — L2 oracle."""
    from .kernels.ref import attention_ref

    s = tokens.shape[0]
    pos = jnp.arange(s, dtype=jnp.int32)
    x = weights["embed"][tokens]
    for layer in weights["layers"]:
        h = rmsnorm(x, layer["attn_norm"])
        q, k, v = _project_qkv(layer, h, cfg)
        q = apply_rope(q, pos, cfg)
        k = apply_rope(k, pos, cfg)
        o = attention_ref(q, k, v, causal=True)
        o = o.transpose(1, 0, 2).reshape(s, cfg.n_heads * cfg.d_head)
        x = x + o @ layer["wo"]
        h = rmsnorm(x, layer["mlp_norm"])
        x = x + _mlp(layer, h)
    x = rmsnorm(x, weights["final_norm"])
    return x @ weights["lm_head"]


# ---------------------------------------------------------------------------
# Batched entry points for AOT (fixed shapes; rust pads to these)
# ---------------------------------------------------------------------------


def make_prefill_fn(cfg: ModelConfig, batch: int, seq: int):
    """Returns a jit-able fn tokens[B,S] -> (logits[B,S,V], k[B,L,Hkv,S,D], v[...])."""
    weights = init_weights(cfg)

    def fn(tokens):
        return jax.vmap(lambda t: prefill(weights, t, cfg))(tokens)

    spec = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    return fn, (spec,)


def make_decode_fn(cfg: ModelConfig, batch: int):
    """Returns fn (token[B], k[B,L,Hkv,MAX,D], v, cur_len[B]) -> (logits[B,V], k', v')."""
    weights = init_weights(cfg)
    maxs = cfg.max_seq

    def fn(token, k_cache, v_cache, cur_len):
        return jax.vmap(
            lambda t, kc, vc, cl: decode_step(weights, t, kc, vc, cl, cfg)
        )(token, k_cache, v_cache, cur_len)

    dh = cfg.d_head
    specs = (
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct(
            (batch, cfg.n_layers, cfg.n_kv_heads, maxs, dh), jnp.float32
        ),
        jax.ShapeDtypeStruct(
            (batch, cfg.n_layers, cfg.n_kv_heads, maxs, dh), jnp.float32
        ),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    )
    return fn, specs
