"""Paged (block-table) decode attention — the PagedAttention-style kernel.

The L3 coordinator manages KV memory as fixed-size pages (see
``rust/src/kvcache/block_allocator.rs``); this kernel is the compute-side
counterpart: a decode query attends to a sequence whose KV lives in
non-contiguous pages of a global pool, addressed through a block table.

Grid: one step per query head. Pages are streamed one at a time through the
online-softmax accumulator, with positions at and beyond ``context_len``
masked. Validated against ``ref.paged_attention_ref``.

interpret=True throughout — see attention.py for the rationale.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _paged_kernel(
    bt_ref,      # [N] int32 block table (scalar-prefetch style input)
    len_ref,     # [1] int32 context length
    q_ref,       # [1, D]
    kp_ref,      # [P, 1, B, D]  pool, this head
    vp_ref,      # [P, 1, B, D]
    o_ref,       # [1, D]
    *,
    scale: float,
):
    d = q_ref.shape[1]
    bsz = kp_ref.shape[2]
    n = bt_ref.shape[0]
    ctx = len_ref[0]

    q = q_ref[0].astype(jnp.float32) * scale  # [D]

    m0 = jnp.full((), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((), dtype=jnp.float32)
    acc0 = jnp.zeros((d,), dtype=jnp.float32)

    def body(i, carry):
        m, l, acc = carry
        page = bt_ref[i]
        k = kp_ref[pl.ds(page, 1)][0, 0]  # [B, D]
        v = vp_ref[pl.ds(page, 1)][0, 0]
        s = k.astype(jnp.float32) @ q  # [B]
        kpos = i * bsz + jax.lax.iota(jnp.int32, bsz)
        mask = kpos >= ctx
        s = jnp.where(mask, NEG_INF, s)
        m_new = jnp.maximum(m, s.max())
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, 0.0, p)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum()
        acc_new = acc * corr + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, n, body, (m0, l0, acc0))
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[0] = (acc / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_attention(
    q,
    k_pages,
    v_pages,
    block_table,
    context_len,
    *,
    scale: float | None = None,
    interpret: bool = True,
):
    """Paged decode attention.

    q            [H, D]          decode query
    k/v_pages    [P, Hkv, B, D]  page pool
    block_table  [N] int32       ordered page ids for this sequence
    context_len  scalar int32    valid token count (<= N*B)
    returns      [H, D]
    """
    h, d = q.shape
    p_, hkv, bsz, _ = k_pages.shape
    assert h % hkv == 0
    rep = h // hkv
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    block_table = jnp.asarray(block_table, dtype=jnp.int32)
    context_len = jnp.asarray(context_len, dtype=jnp.int32).reshape((1,))
    n = block_table.shape[0]

    return pl.pallas_call(
        functools.partial(_paged_kernel, scale=scale),
        grid=(h,),
        in_specs=[
            pl.BlockSpec((n,), lambda ih: (0,)),
            pl.BlockSpec((1,), lambda ih: (0,)),
            pl.BlockSpec((1, d), lambda ih: (ih, 0)),
            pl.BlockSpec((p_, 1, bsz, d), lambda ih: (0, ih // rep, 0, 0)),
            pl.BlockSpec((p_, 1, bsz, d), lambda ih: (0, ih // rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda ih: (ih, 0)),
        out_shape=jax.ShapeDtypeStruct((h, d), q.dtype),
        interpret=interpret,
    )(block_table, context_len, q, k_pages, v_pages)
