"""Pallas attention kernels — the L1 compute hot-spot of the BanaServe stack.

Three kernels, all written flash-attention style (single pass, online
softmax, fp32 accumulators) and all validated against ``ref.py``:

* :func:`flash_attention` — blocked causal MHA/GQA for the prefill path.
* :func:`attention_partial` / :func:`merge_partials` — the paper's
  attention-level migration math (Eqs 6-10): attention over ONE disjoint KV
  partition returns the un-normalized triple ``(o, m, l)``; partitions
  computed on different devices are merged with the numerically-stable
  online-softmax combine. Only ``(m, l)`` (per-row scalars) and the partial
  output cross the device boundary, exactly as Fig 4 describes.
* :func:`decode_attention` — single-query attention over a padded KV cache
  with a dynamic valid length, used by the decode step.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper expresses
the partition across *GPUs*; on TPU the same math tiles across the Pallas
grid — one grid step per (head, q-block), KV streamed through VMEM in
``block_k`` chunks. All ``pallas_call``s use ``interpret=True`` because the
CPU PJRT plugin cannot execute Mosaic custom-calls; on a real TPU the same
BlockSpecs lower natively.

VMEM budgeting (for the DESIGN.md §Perf estimate): per grid step the kernel
holds q-tile ``Bq*D``, k/v tiles ``2*Bk*D``, and accumulators ``Bq*(D+2)``
in fp32 — with the default Bq=Bk=128, D=128 that is ~260 KB, comfortably
inside the ~16 MB VMEM of a TPU core, leaving room for double buffering.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _round_up(a: int, b: int) -> int:
    return _cdiv(a, b) * b


def _pad_axis(x, axis: int, target: int):
    if x.shape[axis] == target:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - x.shape[axis])
    return jnp.pad(x, pads)


# ---------------------------------------------------------------------------
# flash_attention: blocked causal attention for prefill
# ---------------------------------------------------------------------------


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    *,
    scale: float,
    causal: bool,
    q_offset: int,
    kv_len: int,
    block_k: int,
):
    """One (head, q-block) grid step: stream KV in block_k chunks."""
    block_q = q_ref.shape[1]
    d = q_ref.shape[2]
    sk_padded = k_ref.shape[1]
    iq = pl.program_id(1)

    q = q_ref[0].astype(jnp.float32) * scale  # [Bq, D]
    qpos = iq * block_q + jax.lax.iota(jnp.int32, block_q) + q_offset

    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, d), dtype=jnp.float32)

    def body(ik, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice(
            k_ref[0], (ik * block_k, 0), (block_k, d)
        ).astype(jnp.float32)
        v = jax.lax.dynamic_slice(
            v_ref[0], (ik * block_k, 0), (block_k, d)
        ).astype(jnp.float32)
        s = q @ k.T  # [Bq, Bk]
        kpos = ik * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = kpos[None, :] >= kv_len  # padding beyond true length
        if causal:
            mask = mask | (kpos[None, :] > qpos[:, None])
        s = jnp.where(mask, NEG_INF, s)

        m_new = jnp.maximum(m, s.max(axis=-1))
        # exp(NEG_INF - NEG_INF) would be exp(0)=1 for fully-masked rows;
        # guard by re-masking the probability block.
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, 0.0, p)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[:, None] + p @ v
        return m_new, l_new, acc_new

    nk = sk_padded // block_k
    m, l, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, acc0))
    l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> zero output
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_offset", "block_q", "block_k", "scale", "interpret"),
)
def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    q_offset: int = 0,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
):
    """Blocked attention. q [H,Sq,D]; k,v [Hkv,Sk,D] -> [H,Sq,D]."""
    h, sq, d = q.shape
    hkv, sk, _ = k.shape
    assert h % hkv == 0
    rep = h // hkv
    if scale is None:
        scale = 1.0 / math.sqrt(d)

    block_q = min(block_q, _round_up(sq, 8))
    block_k = min(block_k, _round_up(sk, 8))
    sq_p = _round_up(sq, block_q)
    sk_p = _round_up(sk, block_k)
    qp = _pad_axis(q, 1, sq_p)
    kp = _pad_axis(k, 1, sk_p)
    vp = _pad_axis(v, 1, sk_p)

    grid = (h, sq_p // block_q)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            scale=scale,
            causal=causal,
            q_offset=q_offset,
            kv_len=sk,
            block_k=block_k,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda ih, iq: (ih, iq, 0)),
            pl.BlockSpec((1, sk_p, d), lambda ih, iq: (ih // rep, 0, 0)),
            pl.BlockSpec((1, sk_p, d), lambda ih, iq: (ih // rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda ih, iq: (ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, sq_p, d), q.dtype),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :sq, :]


# ---------------------------------------------------------------------------
# attention_partial + merge_partials: the migration math (Eqs 6-10)
# ---------------------------------------------------------------------------


def _partial_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    causal: bool,
    q_offset: int,
    kpos_offset: int,
    kv_len: int,
    block_k: int,
):
    block_q = q_ref.shape[1]
    d = q_ref.shape[2]
    sk_padded = k_ref.shape[1]
    iq = pl.program_id(1)

    q = q_ref[0].astype(jnp.float32) * scale
    qpos = iq * block_q + jax.lax.iota(jnp.int32, block_q) + q_offset

    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, d), dtype=jnp.float32)

    def body(ik, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice(
            k_ref[0], (ik * block_k, 0), (block_k, d)
        ).astype(jnp.float32)
        v = jax.lax.dynamic_slice(
            v_ref[0], (ik * block_k, 0), (block_k, d)
        ).astype(jnp.float32)
        s = q @ k.T
        kpos = ik * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = kpos[None, :] >= kv_len
        if causal:
            abs_kpos = kpos + kpos_offset
            mask = mask | (abs_kpos[None, :] > qpos[:, None])
        s = jnp.where(mask, NEG_INF, s)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, 0.0, p)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[:, None] + p @ v
        return m_new, l_new, acc_new

    nk = sk_padded // block_k
    m, l, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, acc0))
    o_ref[0] = acc
    m_ref[0] = m
    l_ref[0] = l


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal",
        "q_offset",
        "kpos_offset",
        "block_q",
        "block_k",
        "scale",
        "interpret",
    ),
)
def attention_partial(
    q,
    k,
    v,
    *,
    causal: bool = True,
    q_offset: int = 0,
    kpos_offset: int = 0,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
):
    """Partial attention over one KV partition (paper Eqs 6-9).

    Returns ``(o, m, l)`` in fp32: the un-normalized partial output, the row
    max, and the partial softmax denominator. ``kpos_offset`` is the absolute
    position of this partition's first key — causality is evaluated in
    absolute coordinates so disjoint partitions compose.
    """
    h, sq, d = q.shape
    hkv, sk, _ = k.shape
    assert h % hkv == 0
    rep = h // hkv
    if scale is None:
        scale = 1.0 / math.sqrt(d)

    block_q = min(block_q, _round_up(sq, 8))
    block_k = min(block_k, _round_up(sk, 8))
    sq_p = _round_up(sq, block_q)
    sk_p = _round_up(sk, block_k)
    qp = _pad_axis(q, 1, sq_p)
    kp = _pad_axis(k, 1, sk_p)
    vp = _pad_axis(v, 1, sk_p)

    grid = (h, sq_p // block_q)
    o, m, l = pl.pallas_call(
        functools.partial(
            _partial_kernel,
            scale=scale,
            causal=causal,
            q_offset=q_offset,
            kpos_offset=kpos_offset,
            kv_len=sk,
            block_k=block_k,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda ih, iq: (ih, iq, 0)),
            pl.BlockSpec((1, sk_p, d), lambda ih, iq: (ih // rep, 0, 0)),
            pl.BlockSpec((1, sk_p, d), lambda ih, iq: (ih // rep, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda ih, iq: (ih, iq, 0)),
            pl.BlockSpec((1, block_q), lambda ih, iq: (ih, iq)),
            pl.BlockSpec((1, block_q), lambda ih, iq: (ih, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, sq_p, d), jnp.float32),
            jax.ShapeDtypeStruct((h, sq_p), jnp.float32),
            jax.ShapeDtypeStruct((h, sq_p), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return o[:, :sq, :], m[:, :sq], l[:, :sq]


def _merge_kernel(o1_ref, m1_ref, l1_ref, o2_ref, m2_ref, l2_ref, out_ref):
    """Eq 10: combine two partial triples into the normalized output."""
    m1 = m1_ref[0]
    m2 = m2_ref[0]
    m = jnp.maximum(m1, m2)
    c1 = jnp.exp(m1 - m)
    c2 = jnp.exp(m2 - m)
    l = l1_ref[0] * c1 + l2_ref[0] * c2
    l = jnp.where(l == 0.0, 1.0, l)
    o = o1_ref[0] * c1[:, None] + o2_ref[0] * c2[:, None]
    out_ref[0] = (o / l[:, None]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def merge_partials(p1, p2, *, out_dtype=jnp.float32, interpret: bool = True):
    """Merge two disjoint-partition triples (Eq 10) -> [H,Sq,D].

    This is the only cross-device exchange of attention-level migration:
    ``m``/``l`` are [H,Sq] scalars-per-row and ``o`` one partial output.
    """
    o1, m1, l1 = p1
    o2, m2, l2 = p2
    h, sq, d = o1.shape
    spec_o = pl.BlockSpec((1, sq, d), lambda ih: (ih, 0, 0))
    spec_s = pl.BlockSpec((1, sq), lambda ih: (ih, 0))
    return pl.pallas_call(
        _merge_kernel,
        grid=(h,),
        in_specs=[spec_o, spec_s, spec_s, spec_o, spec_s, spec_s],
        out_specs=spec_o,
        out_shape=jax.ShapeDtypeStruct((h, sq, d), out_dtype),
        interpret=interpret,
    )(o1, m1, l1, o2, m2, l2)


def split_attention(q, k, v, split: int, *, causal: bool = True, interpret=True):
    """End-to-end attention-level migration: hot partition [0,split), cold
    partition [split,Sk), merged per Eq 10. Must equal flash_attention."""
    p1 = attention_partial(q, k[:, :split], v[:, :split], causal=causal, interpret=interpret)
    p2 = attention_partial(
        q,
        k[:, split:],
        v[:, split:],
        kpos_offset=split,
        causal=causal,
        interpret=interpret,
    )
    return merge_partials(p1, p2, out_dtype=q.dtype, interpret=interpret)


# ---------------------------------------------------------------------------
# decode_attention: single new token vs padded cache
# ---------------------------------------------------------------------------


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, scale: float, block_k: int):
    d = q_ref.shape[1]
    sk_padded = k_ref.shape[1]
    kv_len = len_ref[0]

    q = q_ref[0].astype(jnp.float32) * scale  # [1, D] row

    m0 = jnp.full((1,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((1,), dtype=jnp.float32)
    acc0 = jnp.zeros((1, d), dtype=jnp.float32)

    def body(ik, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice(
            k_ref[0], (ik * block_k, 0), (block_k, d)
        ).astype(jnp.float32)
        v = jax.lax.dynamic_slice(
            v_ref[0], (ik * block_k, 0), (block_k, d)
        ).astype(jnp.float32)
        s = q @ k.T  # [1, Bk]
        kpos = ik * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = kpos[None, :] >= kv_len
        s = jnp.where(mask, NEG_INF, s)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, 0.0, p)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[:, None] + p @ v
        return m_new, l_new, acc_new

    nk = sk_padded // block_k
    m, l, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, acc0))
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)[0]


@functools.partial(
    jax.jit, static_argnames=("scale", "block_k", "interpret")
)
def decode_attention(
    q,
    k,
    v,
    kv_len,
    *,
    scale: float | None = None,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
):
    """Single-query attention over a padded cache.

    q [H,D]; k,v [Hkv,Smax,D]; kv_len scalar int32 (valid prefix length).
    Returns [H,D]. Positions >= kv_len are masked — this is the kernel the
    decode step uses against its (possibly migrated) KV cache.
    """
    h, d = q.shape
    hkv, smax, _ = k.shape
    assert h % hkv == 0
    rep = h // hkv
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    block_k = min(block_k, _round_up(smax, 8))
    smax_p = _round_up(smax, block_k)
    kp = _pad_axis(k, 1, smax_p)
    vp = _pad_axis(v, 1, smax_p)
    kv_len = jnp.asarray(kv_len, dtype=jnp.int32).reshape((1,))

    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_k=block_k),
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, d), lambda ih: (ih, 0)),
            pl.BlockSpec((1, smax_p, d), lambda ih: (ih // rep, 0, 0)),
            pl.BlockSpec((1, smax_p, d), lambda ih: (ih // rep, 0, 0)),
            pl.BlockSpec((1,), lambda ih: (0,)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda ih: (ih, 0)),
        out_shape=jax.ShapeDtypeStruct((h, d), q.dtype),
        interpret=interpret,
    )(q, kp, vp, kv_len)
