"""Pure-jnp correctness oracles for the Pallas kernels.

Everything here is deliberately naive: direct softmax(QK^T)V with explicit
masks, no online-softmax tricks, no blocking. The Pallas kernels in
``attention.py`` / ``paged.py`` must match these to numerical tolerance —
pytest + hypothesis sweep shapes/dtypes against these functions.

Shapes follow the convention used across the repo:
  q        [H, Sq, D]      query heads
  k, v     [Hkv, Sk, D]    key/value heads (GQA: H % Hkv == 0)
  output   [H, Sq, D]
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def repeat_kv(x: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """Expand KV heads for grouped-query attention: [Hkv,S,D] -> [Hkv*n_rep,S,D]."""
    if n_rep == 1:
        return x
    hkv, s, d = x.shape
    return jnp.broadcast_to(x[:, None, :, :], (hkv, n_rep, s, d)).reshape(
        hkv * n_rep, s, d
    )


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset: int = 0,
    kv_len: int | None = None,
    scale: float | None = None,
) -> jnp.ndarray:
    """Reference multi-head attention.

    ``q_offset`` is the absolute position of q[0] within the key sequence —
    used for the decode step, where a single new query attends to a long
    cache. ``kv_len`` masks out cache positions >= kv_len (padded caches).
    """
    h, sq, d = q.shape
    hkv = k.shape[0]
    assert h % hkv == 0, f"GQA mismatch: {h} query heads vs {hkv} kv heads"
    k = repeat_kv(k, h // hkv)
    v = repeat_kv(v, h // hkv)
    sk = k.shape[1]
    if scale is None:
        scale = 1.0 / (d**0.5)

    scores = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * scale

    mask = jnp.zeros((sq, sk), dtype=bool)
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(sk)[None, :]
        mask = mask | (kpos > qpos)
    if kv_len is not None:
        mask = mask | (jnp.arange(sk)[None, :] >= kv_len)
    scores = jnp.where(mask[None, :, :], NEG_INF, scores)

    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("hqk,hkd->hqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_partial_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    kpos_offset: int = 0,
    q_offset: int = 0,
    causal: bool = True,
    scale: float | None = None,
):
    """Partial attention over one KV partition (paper Eqs 6-9).

    Returns the *unmerged* triple ``(o, m, l)`` where
      m [H,Sq]   running row max of the scaled scores,
      l [H,Sq]   sum of exp(score - m),
      o [H,Sq,D] sum of exp(score - m) * V  (un-normalized output).

    Two disjoint partitions merged with :func:`merge_partials_ref` must equal
    :func:`attention_ref` over the concatenated KV — this is the correctness
    contract of BanaServe's attention-level migration (Eq 10).
    """
    h, sq, d = q.shape
    hkv = k.shape[0]
    k = repeat_kv(k, h // hkv)
    v = repeat_kv(v, h // hkv)
    if scale is None:
        scale = 1.0 / (d**0.5)
    sk = k.shape[1]

    scores = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * scale
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(sk)[None, :] + kpos_offset
        scores = jnp.where((kpos > qpos)[None, :, :], NEG_INF, scores)

    m = scores.max(axis=-1)
    e = jnp.exp(scores - m[:, :, None])
    l = e.sum(axis=-1)
    o = jnp.einsum("hqk,hkd->hqd", e, v.astype(jnp.float32))
    return o, m, l


def merge_partials_ref(parts):
    """Merge partial-softmax triples from disjoint KV partitions (Eq 10).

    Numerically-stable online-softmax combine:
      m* = max_j m_j;  l* = sum_j l_j * exp(m_j - m*);
      O  = sum_j o_j * exp(m_j - m*) / l*.
    """
    o0, m0, l0 = parts[0]
    for o1, m1, l1 in parts[1:]:
        m = jnp.maximum(m0, m1)
        c0 = jnp.exp(m0 - m)
        c1 = jnp.exp(m1 - m)
        l0 = l0 * c0 + l1 * c1
        o0 = o0 * c0[:, :, None] + o1 * c1[:, :, None]
        m0 = m
    return o0 / l0[:, :, None]


def split_attention_ref(q, k, v, split: int, *, causal: bool = True):
    """Attention computed as two KV-sequence partitions then merged.

    Models BanaServe attention-level migration: partition [0,split) stays on
    the hot device, [split,Sk) is offloaded; only (m,l,o) are exchanged.
    """
    p1 = attention_partial_ref(q, k[:, :split], v[:, :split], causal=causal)
    p2 = attention_partial_ref(
        q, k[:, split:], v[:, split:], kpos_offset=split, causal=causal
    )
    return merge_partials_ref([p1, p2]).astype(q.dtype)


def head_split_attention_ref(q, k, v, head_split: int, *, causal: bool = True):
    """Attention with disjoint *head* partitions (paper Fig 4 narrative).

    Head partitions are embarrassingly parallel — outputs concatenate, no
    denominator exchange. Included as the second migration axis.
    ``head_split`` counts query heads and must align to the GQA group size.
    """
    h = q.shape[0]
    hkv = k.shape[0]
    rep = h // hkv
    assert head_split % rep == 0
    kv_split = head_split // rep
    o1 = attention_ref(q[:head_split], k[:kv_split], v[:kv_split], causal=causal)
    o2 = attention_ref(q[head_split:], k[kv_split:], v[kv_split:], causal=causal)
    return jnp.concatenate([o1, o2], axis=0)


def paged_attention_ref(q, k_pages, v_pages, block_table, context_len, *, scale=None):
    """Reference for paged decode attention.

    q            [H, D]            single decode query
    k/v_pages    [P, Hkv, B, D]    global page pool (B = page/block size)
    block_table  [N]               int32 page ids of this sequence, in order
    context_len  scalar            number of valid tokens (<= N*B)
    """
    h, d = q.shape
    hkv = k_pages.shape[1]
    bsz = k_pages.shape[2]
    n = block_table.shape[0]
    # Gather pages -> contiguous [Hkv, N*B, D]
    k = k_pages[block_table]  # [N, Hkv, B, D]
    v = v_pages[block_table]
    k = jnp.transpose(k, (1, 0, 2, 3)).reshape(hkv, n * bsz, d)
    v = jnp.transpose(v, (1, 0, 2, 3)).reshape(hkv, n * bsz, d)
    out = attention_ref(
        q[:, None, :],
        k,
        v,
        causal=False,
        kv_len=context_len,
        scale=scale,
    )
    return out[:, 0, :]


def swiglu_ref(x, w_gate, w_up, w_down):
    """Reference SwiGLU MLP: down( silu(x@gate) * (x@up) )."""
    xf = x.astype(jnp.float32)
    g = xf @ w_gate.astype(jnp.float32)
    u = xf @ w_up.astype(jnp.float32)
    act = g * (1.0 / (1.0 + jnp.exp(-g)))
    return ((act * u) @ w_down.astype(jnp.float32)).astype(x.dtype)


def rmsnorm_ref(x, w, eps: float = 1e-5):
    """Reference RMSNorm."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * (1.0 / jnp.sqrt(var + eps)) * w.astype(jnp.float32)).astype(x.dtype)
