//! Long-context + shared prefixes: LongBench-like prompts where many
//! requests share few-shot preambles. Shows the Global KV Cache Store's
//! effect — cross-instance prefix reuse cutting prefill compute — plus the
//! Fig 6 pipeline check that makes the store latency-transparent.
//!
//!     cargo run --release --example longcontext_cache

use banaserve::config::{EngineKind, ExperimentConfig};
use banaserve::engines::run_experiment;
use banaserve::model::LLAMA31_8B;
use banaserve::perfmodel;
use banaserve::workload::{LengthProfile, WorkloadConfig};

fn main() {
    banaserve::util::logging::init(log::Level::Warn);
    println!("== Global KV Cache Store on long-context workloads ==\n");

    // Fig 6 feasibility numbers first (paper's worked example)
    let t_f_layer = perfmodel::per_layer_forward_time(0.270, 0.5, LLAMA31_8B.n_layers);
    let t_kv = perfmodel::per_layer_kv_transfer_time(
        LLAMA31_8B.kv_bytes_per_token_layer(),
        1000,
        0.5,
        banaserve::cluster::NET_200GBPS.bandwidth,
    );
    println!(
        "layer-wise pipeline: T_F,layer = {:.2} ms  vs  T_KV = {:.3} ms  -> transfers {}",
        t_f_layer * 1e3,
        t_kv * 1e3,
        if perfmodel::pipeline_hides_transfer(t_f_layer, t_kv) {
            "fully hidden"
        } else {
            "NOT hidden"
        }
    );

    println!("\nLongBench-like prompts, 60% sharing few-shot preambles, 6 RPS:\n");
    for (label, store) in [("store ON ", true), ("store OFF", false)] {
        let mut c = ExperimentConfig::default_for(EngineKind::BanaServe, "llama-13b", 6.0, 29);
        c.workload = WorkloadConfig::poisson(LengthProfile::LongBench, 6.0, 60.0, 29);
        c.workload.prefix.share_prob = 0.6;
        c.warmup = 5.0;
        c.bana.global_store = store;
        let out = run_experiment(&c);
        println!(
            "{label}  tput {:>7.1} tok/s   ttft(mean) {:>7.2}s   cached tokens {:>9}   hit rate {:.2}",
            out.report.throughput_tok_s,
            out.report.ttft.mean(),
            out.report.cached_tokens,
            out.extras.store_hit_rate,
        );
    }
    println!("\nwith the store, every prefill node reuses every cached prefix —");
    println!("the router needs no cache awareness at all (paper Fig 5 / Alg 2).");
}
