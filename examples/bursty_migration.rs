//! Bursty arrivals: the scenario the paper's intro motivates — traffic
//! spikes that static PD splits cannot absorb. Compares DistServe's static
//! 2/2 split against BanaServe under an on/off modulated Poisson process
//! (5x bursts), reporting tail latency and throughput.
//!
//!     cargo run --release --example bursty_migration

use banaserve::config::{EngineKind, ExperimentConfig};
use banaserve::engines::run_experiment;
use banaserve::workload::{ArrivalProcess, LengthProfile, WorkloadConfig};

fn main() {
    banaserve::util::logging::init(log::Level::Warn);
    println!("== Bursty workload: 5x spikes every 60s (paper §2.4) ==\n");
    for kind in [EngineKind::Vllm, EngineKind::DistServe, EngineKind::BanaServe] {
        let mut c = ExperimentConfig::default_for(kind, "llama-13b", 6.0, 17);
        c.workload = WorkloadConfig::poisson(LengthProfile::AlpacaShort, 6.0, 120.0, 17);
        c.workload.arrivals = ArrivalProcess::Bursty {
            rps: 6.0,
            burst_factor: 5.0,
            burst_secs: 15.0,
            period_secs: 60.0,
        };
        c.warmup = 5.0;
        let out = run_experiment(&c);
        let mut e2e = out.report.e2e.clone();
        println!(
            "{:<10} tput {:>7.1} tok/s   total {:>7.1}s   p50 {:>6.2}s   p99 {:>7.2}s   migrations {}L/{}A",
            c.engine.name(),
            out.report.throughput_tok_s,
            out.report.makespan,
            e2e.p50(),
            e2e.p99(),
            out.extras.layer_migrations,
            out.extras.attention_migrations,
        );
    }
    println!("\nBanaServe absorbs the spikes by temporarily re-rolling devices;");
    println!("the static split pays for them in queueing tail latency.");
}
