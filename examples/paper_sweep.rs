//! Full paper sweep in miniature: regenerates the Fig 8 (LLaMA-13B short
//! context) comparison at reduced duration/seeds so it finishes quickly.
//! For the full-fidelity runs use `cargo bench --bench fig8_llama_short`.
//!
//!     cargo run --release --example paper_sweep

use banaserve::bench_support::{print_figure, run_cell};
use banaserve::config::{EngineKind, ExperimentConfig};
use banaserve::workload::{LengthProfile, WorkloadConfig};

fn main() {
    banaserve::util::logging::init(log::Level::Warn);
    let engines = [EngineKind::Vllm, EngineKind::DistServe, EngineKind::BanaServe];
    let mut cells = Vec::new();
    for rps in [2.0, 8.0, 14.0, 20.0] {
        for e in engines {
            cells.push(run_cell(e, rps, &[11, 23], |e, rps, seed| {
                let mut c = ExperimentConfig::default_for(e, "llama-13b", rps, seed);
                c.workload =
                    WorkloadConfig::poisson(LengthProfile::AlpacaShort, rps, 45.0, seed);
                c.warmup = 5.0;
                c
            }));
        }
    }
    print_figure(
        "Fig 8 (reduced): LLaMA-13B short-context, 2 seeds x 45s",
        &engines,
        &cells,
    );
}
