//! Migration demo: watch Algorithm 1 rebalance a deliberately mis-split
//! cluster. We start BanaServe with 3 prefill / 1 decode devices under a
//! decode-heavy short-context workload — the orchestrator must shift layer
//! share toward decode, and throughput must beat the same mis-split
//! without migration.
//!
//!     cargo run --release --example migration_demo

use banaserve::config::{EngineKind, ExperimentConfig};
use banaserve::engines::run_experiment;
use banaserve::workload::{LengthProfile, WorkloadConfig};

fn main() {
    banaserve::util::logging::init(log::Level::Warn);
    println!("== Dynamic module migration (paper Alg 1) ==\n");
    println!("cluster: 4 devices mis-split as 3 prefill / 1 decode");
    println!("workload: Alpaca-like short prompts, 14 RPS (decode-bound)\n");

    let mk = |migrate: bool| {
        let mut c = ExperimentConfig::default_for(EngineKind::BanaServe, "llama-13b", 14.0, 5);
        c.n_devices = 4;
        c.n_prefill = 3; // deliberately wrong for a decode-heavy load
        c.workload = WorkloadConfig::poisson(LengthProfile::AlpacaShort, 14.0, 60.0, 5);
        c.warmup = 5.0;
        c.bana.layer_migration = migrate;
        c.bana.attention_migration = migrate;
        c
    };

    let frozen = run_experiment(&mk(false));
    let adaptive = run_experiment(&mk(true));

    println!("static mis-split (no migration):");
    println!("  {}", frozen.report.one_line());
    println!("with dynamic migration:");
    println!("  {}", adaptive.report.one_line());
    println!(
        "  layer migrations: {}   attention migrations: {}",
        adaptive.extras.layer_migrations, adaptive.extras.attention_migrations
    );
    let speedup = adaptive.report.throughput_tok_s / frozen.report.throughput_tok_s;
    println!("\nthroughput gain from migration: {speedup:.2}x");
    println!("(the orchestrator converts idle prefill capacity into decode capacity,");
    println!(" exactly the §4.1 'dynamic resource allocation' claim)");
}
