//! Quickstart — THE end-to-end driver: loads the AOT-compiled tiny
//! transformer (JAX + Pallas kernels -> HLO text -> PJRT CPU), serves a
//! batch of real requests through the threaded continuous-batching
//! coordinator, and reports latency/throughput.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This is the proof that all three layers compose: the Pallas attention
//! kernels execute inside the HLO the rust coordinator schedules; python
//! is never on the request path.

use banaserve::coordinator::{serve, ServeConfig, ServeRequest};
use banaserve::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    banaserve::util::logging::init(log::Level::Warn);

    let cfg = ServeConfig {
        artifacts_dir: "artifacts".into(),
        variant: "tiny".into(),
        n_workers: 2,
        batch: 4,
    };
    let mut rng = Rng::new(7);
    let requests: Vec<ServeRequest> = (0..24)
        .map(|i| {
            let len = rng.range(4, 28) as usize;
            ServeRequest {
                id: i,
                prompt: (0..len).map(|_| rng.below(256) as i32).collect(),
                max_new_tokens: 32,
            }
        })
        .collect();

    println!("== BanaServe quickstart: real model, real serving path ==");
    println!(
        "loading AOT artifacts + compiling on PJRT CPU, then serving {} requests\n",
        requests.len()
    );
    let (responses, stats) = serve(&cfg, requests)?;

    for r in responses.iter().take(5) {
        println!(
            "req {:>2} [worker {}]  {} tokens   ttft {:>9.3?}   e2e {:>9.3?}   first tokens {:?}",
            r.id,
            r.worker,
            r.tokens.len(),
            r.ttft,
            r.e2e,
            &r.tokens[..4.min(r.tokens.len())]
        );
    }
    println!("  ... ({} more)", responses.len().saturating_sub(5));
    println!(
        "\ncompleted {} requests / {} generated tokens in {:?}",
        stats.completed, stats.total_generated, stats.wall
    );
    println!(
        "throughput {:.1} tok/s   mean TTFT {:?}   mean E2E {:?}",
        stats.throughput_tok_s, stats.mean_ttft, stats.mean_e2e
    );
    Ok(())
}
